"""daism-lint: the static analyzer's site graph and every checker family.

Each checker must fire on a crafted bad (model, policy, engine) triple and
stay silent (no error findings) on every shipped config's defaults — the
same invariant the CI `lint-policies` job enforces end to end.
"""
import dataclasses
import json

import pytest

from repro.analyze import (analyze, check_backend, check_policy,
                           check_recompile, check_serving, check_tiling,
                           engine_config_finding, format_json, format_text,
                           preflight, trace_site_graph)
from repro.configs import ARCH_IDS, PAPER_IDS, get_config
from repro.core import Backend, DaismConfig, Variant
from repro.serve import EngineConfig

PC3_TR = DaismConfig(variant=Variant.PC3_TR, backend=Backend.JNP)


def codes(findings):
    return {f.code for f in findings}


def smoke_lm():
    return get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)


# ---------------------------------------------------------------------------
# Site-graph tracing (eval_shape only — no weights, no kernels)
# ---------------------------------------------------------------------------

def test_trace_site_graph_covers_all_sites_without_weights():
    graph = trace_site_graph(smoke_lm(), "*/attn/*=exact,*=pc3_tr")
    paths = graph.paths()
    assert any("attn" in p for p in paths)
    assert any("ffn" in p for p in paths)
    assert any("lm_head" in p for p in paths)
    assert all(s.macs > 0 for s in graph.sites)
    used, exact = graph.energy_uj()
    assert 0 < used < exact  # mixed policy lands strictly below all-exact


def test_trace_site_graph_matches_runtime_segmentation():
    graph = trace_site_graph(smoke_lm(), "*/layer_0/*=exact,*=pc3_tr")
    # layer_0 exact / layer_1 approx must shatter the decoder scan in two
    assert any(len(spans) == 2 for spans in graph.segments.values())
    assert any("layer_0" in p for p in graph.paths())


def test_trace_handles_illegal_candidate_policy():
    """Policies the ArchConfig would reject (bf16-only backend on an fp32
    model) still trace — legality is a finding, not a crash."""
    graph = trace_site_graph(get_config("lenet5"), "*=pc3_tr:lut")
    assert graph.sites  # traced anyway
    bck = check_backend(graph)
    assert bck and all(f.code == "BCK001" and f.severity == "error"
                       for f in bck)


# ---------------------------------------------------------------------------
# Policy checkers
# ---------------------------------------------------------------------------

def test_zero_match_rule_is_an_error():
    report = analyze(smoke_lm(), "*/bogus/*=exact,*=pc3_tr")
    assert "POL001" in codes(report.errors)
    assert report.exit_code == 1


def test_shadowed_and_catch_all_ordering_warn():
    graph = trace_site_graph(smoke_lm(), "*=pc3_tr,*/attn/*=exact")
    found = codes(check_policy(graph))
    assert {"POL002", "POL003"} <= found  # shadowed + catch-all-first


def test_deprecated_daism_shim_warns():
    cfg = dataclasses.replace(smoke_lm(), daism=PC3_TR, policy=None)
    found = check_policy(trace_site_graph(cfg))
    assert "POL004" in codes(found)


# ---------------------------------------------------------------------------
# Tiling / recompile checkers
# ---------------------------------------------------------------------------

def test_tiling_padding_and_vmem_warnings():
    from repro.policy import EXACT, ApproxPolicy, Rule
    # spec grammar has no block syntax: build the policy programmatically.
    # The fused plane sweep made the VMEM estimate K-independent (live slabs
    # are (bm, K_FUSE, bn)), so only very large M/N tiles can blow the
    # budget now — block_k only enters through the streamed bf16 tiles.
    bad = DaismConfig(variant=Variant.PC3_TR, backend=Backend.PALLAS,
                      block_m=2048, block_n=1000, block_k=2048)
    pol = ApproxPolicy(rules=(Rule("*/ffn/*", bad),), default=EXACT)
    graph = trace_site_graph(smoke_lm(), pol)
    found = codes(check_tiling(graph))
    assert {"TIL001", "TIL002"} <= found


def test_tiling_interpret_fallback_info_on_cpu():
    graph = trace_site_graph(smoke_lm(), "*=pc3_tr:pallas")
    til = check_tiling(graph)
    assert "TIL003" in codes(til)
    assert all(f.severity in ("info", "warning") for f in til)


def test_attention_checker_flags_ragged_flash_tiles():
    from repro.analyze import check_attention
    # seq=8 pads to the 128-wide flash tiles; head_dim 64 is off-lane too
    graph = trace_site_graph(smoke_lm(),
                             "*/attn/kernel=exact:flash,*=exact")
    found = check_attention(graph)
    assert any(f.code == "TIL004" and f.severity == "warning"
               and f.site.endswith("attn/kernel") for f in found)
    # without the ':flash' opt-in the ATTN_QK sites run exact jnp — silent
    assert not check_attention(trace_site_graph(smoke_lm(), "*=pc3_tr"))


def test_attention_checker_flags_non_bf16_flash_variant():
    from repro.analyze import check_attention
    cfg = dataclasses.replace(smoke_lm(), compute_dtype="float32",
                              param_dtype="float32")
    graph = trace_site_graph(cfg, "*/attn/kernel=pc3_tr:flash,*=exact")
    found = check_attention(graph)
    assert any(f.code == "TIL005" and f.severity == "error" for f in found)


def test_recompile_hazards_on_depth_schedule():
    from repro.policy import ApproxPolicy, Rule
    cfg = get_config("tinyllama_1_1b")  # full depth: 22 layers
    rules = tuple(
        Rule(f"*/layer_{i}/*", dataclasses.replace(PC3_TR, k_chunk=64 + i))
        for i in range(cfg.n_layers))
    graph = trace_site_graph(cfg, ApproxPolicy(rules=rules, default=PC3_TR))
    found = codes(check_recompile(graph))
    assert {"RCP001", "RCP002"} <= found  # segment shatter + kernel variants


# ---------------------------------------------------------------------------
# Serving checkers
# ---------------------------------------------------------------------------

def test_serving_window_incompatibility_is_an_error():
    cfg = dataclasses.replace(smoke_lm(), window=16)
    graph = trace_site_graph(cfg)
    found = check_serving(graph, EngineConfig())
    assert any(f.code == "SRV001" and f.severity == "error" for f in found)


def test_serving_pool_capacity_and_oversubscription():
    graph = trace_site_graph(smoke_lm())
    small = EngineConfig(num_blocks=4, block_size=16)  # 64 < max_seq=128
    found = check_serving(graph, small)
    assert "SRV002" in codes(found)
    tiered = EngineConfig(num_blocks=32, block_size=16,
                          tiers=(("free", "*=pc3_tr"),
                                 ("paid", "*/attn/*=exact,*=pc3_tr")))
    found = check_serving(graph, tiered)
    assert "SRV003" in codes(found)  # 512 blocks*size < slots*tiers*max_seq


def test_serving_duplicate_tier_groups_and_bad_tier_spec():
    graph = trace_site_graph(smoke_lm())
    dup = EngineConfig(tiers=(("free", "*=pc3_tr"), ("paid", "*=pc3_tr")))
    assert "SRV004" in codes(check_serving(graph, dup))
    broken = EngineConfig(tiers=(("free", "*/xx/*=exact,*=pc3_tr"),))
    found = check_serving(graph, broken)
    assert "SRV005" in codes(found)


def test_serving_shard_divisibility_srv007():
    graph = trace_site_graph(smoke_lm())
    # 30 pages / 4 slots over 4 shards: pages don't divide
    bad = EngineConfig(num_slots=4, num_blocks=30, block_size=16, shards=4)
    found = check_serving(graph, bad)
    assert any(f.code == "SRV007" and f.severity == "error" for f in found)
    # rows don't divide either
    bad_rows = EngineConfig(num_slots=3, num_blocks=32, block_size=16,
                            shards=4)
    assert "SRV007" in codes(check_serving(graph, bad_rows))
    ok = EngineConfig(num_slots=4, num_blocks=32, block_size=16, shards=4)
    assert "SRV007" not in codes(check_serving(graph, ok))
    # advisory mode caps it to a warning like the other structural errors
    found = check_serving(graph, bad, advisory=True)
    assert any(f.code == "SRV007" and f.severity == "warning" for f in found)


def test_serving_undersized_swap_buffer_srv008():
    graph = trace_site_graph(smoke_lm())
    # max_seq=128 / block_size=16 -> 8 pages per max-length request
    small = EngineConfig(preempt=True, swap_blocks=4)
    found = check_serving(graph, small)
    assert any(f.code == "SRV008" and f.severity == "warning" for f in found)
    # 0 = auto (one full request) and >= one request are both fine
    assert "SRV008" not in codes(
        check_serving(graph, EngineConfig(preempt=True)))
    assert "SRV008" not in codes(
        check_serving(graph, EngineConfig(preempt=True, swap_blocks=8)))
    # without preemption the swap buffer is never used
    assert "SRV008" not in codes(
        check_serving(graph, EngineConfig(swap_blocks=4)))


def test_serving_advisory_mode_caps_severity():
    cfg = dataclasses.replace(smoke_lm(), window=16)
    graph = trace_site_graph(cfg)
    found = check_serving(graph, EngineConfig(), advisory=True)
    assert any(f.code == "SRV001" for f in found)
    assert all(f.severity != "error" for f in found)


def test_serving_skipped_for_non_servable_family():
    graph = trace_site_graph(get_config("lenet5"))
    found = check_serving(graph, EngineConfig())
    assert codes(found) == {"SRV006"}
    assert all(f.severity == "info" for f in found)


def test_serving_spec_draft_srv009():
    """SRV009: speculative draft vs target compatibility — energy, dtype,
    window, spec parse; silent when the draft is genuinely cheaper."""
    graph = trace_site_graph(smoke_lm())  # target: exact base policy

    def srv9(ecfg, **kw):
        return [f for f in check_serving(graph, ecfg, **kw)
                if f.code == "SRV009"]

    # a genuinely cheaper draft is clean
    ok = EngineConfig(spec_draft="*=pc3_tr", spec_k=3)
    assert srv9(ok) == []
    # ... and spec_k=0 never runs the checker at all
    assert srv9(EngineConfig()) == []

    # draft == target numerics: speculation can never pay for itself
    found = srv9(EngineConfig(spec_draft="*=exact", spec_k=3))
    assert [f.severity for f in found] == ["error"]
    assert "not cheaper" in found[0].message

    # draft names a registered tier (resolved through EngineConfig.tiers)
    named = EngineConfig(tiers=(("cheap", "*=pc3_tr"),),
                         spec_draft="cheap", spec_k=3)
    assert srv9(named) == []

    # draft not cheaper than another tier: warning, not error
    found = srv9(EngineConfig(tiers=(("cheap", "*=pc3_tr"),),
                              spec_draft="*=pc2", spec_k=3))
    assert any(f.severity == "warning" and "tier 'cheap'" in f.message
               for f in found)

    # unparseable draft spec
    found = srv9(EngineConfig(spec_draft="*=bogus", spec_k=3))
    assert [f.severity for f in found] == ["error"]
    assert "rejected" in found[0].message

    # windowed model: draft writes ahead of the committed length
    wg = trace_site_graph(dataclasses.replace(smoke_lm(), window=16))
    found = [f for f in check_serving(wg, ok) if f.code == "SRV009"]
    assert any("window" in f.message and f.severity == "error"
               for f in found)

    # dtype illegality: LUT draft on an f32 model
    f32 = dataclasses.replace(smoke_lm(), compute_dtype="float32",
                              param_dtype="float32")
    fg = trace_site_graph(f32)
    found = [f for f in check_serving(
        fg, EngineConfig(spec_draft="*=pc3_tr:lut", spec_k=3))
        if f.code == "SRV009"]
    assert any(f.severity == "error" for f in found)

    # advisory mode downgrades the structural errors to warnings
    found = srv9(EngineConfig(spec_draft="*=exact", spec_k=3),
                 advisory=True)
    assert found and all(f.severity == "warning" for f in found)


def test_engine_config_finding_wraps_construction_error():
    try:
        EngineConfig(tiers=(("free",),))  # malformed pair
    except ValueError as e:
        f = engine_config_finding(e)
        assert f.code == "SRV000" and f.severity == "error"
    else:
        pytest.fail("malformed tiers must not construct")


# ---------------------------------------------------------------------------
# Reports, preflight, and the shipped-config sweep
# ---------------------------------------------------------------------------

def test_report_formats_and_exit_codes():
    report = analyze(smoke_lm(), "*/attn/*=exact,*=pc3_tr")
    assert report.exit_code == 0
    text = format_text(report)
    assert "daism-lint" in text and "ENE001" in text
    data = json.loads(format_json(report))
    assert data["exit_code"] == 0
    assert data["sites"] and data["findings"]
    assert set(data["energy_uj"]) == {"policy", "exact"}


def test_preflight_raises_on_error_findings(capsys):
    with pytest.raises(SystemExit, match="daism-lint found"):
        preflight(smoke_lm(), "*/bogus/*=exact,*=pc3_tr", label="train t")
    out = capsys.readouterr().out
    assert "POL001" in out


def test_preflight_passes_clean_config():
    report = preflight(smoke_lm(), serving=False, label="train t")
    assert report.exit_code == 0


@pytest.mark.parametrize("name", list(ARCH_IDS) + list(PAPER_IDS))
def test_all_shipped_configs_lint_clean(name):
    """The CI sweep invariant: every registered config's defaults produce
    zero error findings (serving advisory, as nothing is deployed)."""
    report = analyze(name, advisory_serving=True)
    assert report.errors == [], [str(f) for f in report.errors]
    assert report.graph.sites
