"""GPipe pipeline parallelism: numerical equivalence with sequential scan.

Runs in a subprocess (needs 4 fake devices for a 4-stage mesh; the main
pytest process keeps the default single-device environment)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from repro.parallel.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("stage",))
    L, B, D = 8, 16, 32
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(L, D, D)) / np.sqrt(D),
                               jnp.float32),
              "b": jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def layer(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    # sequential reference
    def seq(params, x):
        def body(h, p):
            return layer(p, h), None
        h, _ = lax.scan(body, x, params)
        return h

    ref = seq(params, x)
    for m in (2, 4, 8):
        out = pipeline_apply(layer, params, x, mesh, n_microbatches=m)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    # gradients flow through the pipeline (ppermute transpose)
    g_pipe = jax.grad(lambda p: (pipeline_apply(
        layer, p, x, mesh, n_microbatches=4) ** 2).sum())(params)
    g_seq = jax.grad(lambda p: (seq(p, x) ** 2).sum())(params)
    for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    print("PIPELINE-OK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "PIPELINE-OK" in out.stdout, out.stderr[-3000:]
