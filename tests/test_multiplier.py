"""Bit-level unit + property tests for the DAISM multiplier family."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev extra")
from hypothesis import given, settings, strategies as st

from repro.core import Variant, error_distance
from repro.core.bitops import exact_mul_planes
from repro.core.multiplier import (approx_mul_int_signmag, approx_mul_uint,
                                   approx_mul_uint_planes)

VARIANTS = [Variant.FLA, Variant.HLA, Variant.PC2, Variant.PC3,
            Variant.PC2_TR, Variant.PC3_TR]


def _fla_oracle(a, b, n=8):
    out = np.zeros_like(a)
    for i in range(n):
        out |= np.where((b >> i) & 1 == 1, a << i, 0)
    return out


@pytest.fixture(scope="module")
def pairs():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, (4000,)).astype(np.int32)
    b = rng.integers(0, 256, (4000,)).astype(np.int32)
    return jnp.asarray(a), jnp.asarray(b), a, b


def test_fla_matches_numpy_oracle(pairs):
    ja, jb, a, b = pairs
    got = np.asarray(approx_mul_uint(ja, jb, 8, Variant.FLA))
    np.testing.assert_array_equal(got, _fla_oracle(a, b))


def test_ordering_fla_hla_exact(pairs):
    """max(p_i) <= FLA <= HLA <= exact (paper §3.1/3.2)."""
    ja, jb, a, b = pairs
    fla = np.asarray(approx_mul_uint(ja, jb, 8, Variant.FLA))
    hla = np.asarray(approx_mul_uint(ja, jb, 8, Variant.HLA))
    exact = a * b
    assert (fla <= hla).all()
    assert (hla <= exact).all()
    # FLA >= the largest selected partial product
    maxp = np.zeros_like(a)
    for i in range(8):
        maxp = np.maximum(maxp, np.where((b >> i) & 1 == 1, a << i, 0))
    assert (fla >= maxp).all()


def test_fla_is_symmetric(pairs):
    ja, jb, *_ = pairs
    f1 = np.asarray(approx_mul_uint(ja, jb, 8, Variant.FLA))
    f2 = np.asarray(approx_mul_uint(jb, ja, 8, Variant.FLA))
    np.testing.assert_array_equal(f1, f2)


def test_exact_when_single_bit_multiplicand(pairs):
    """Paper: multiplicand 64 (1000000) never collides => FLA exact."""
    _, jb, _, b = pairs
    a64 = jnp.full_like(jb, 64)
    got = np.asarray(approx_mul_uint(a64, jb, 8, Variant.FLA))
    np.testing.assert_array_equal(got, 64 * b)


@pytest.mark.parametrize("variant", VARIANTS)
def test_upper_bound_and_truncation(pairs, variant):
    # msb_always_set is the float-mantissa mode: only valid for b >= 128
    # (the implicit leading 1); restrict operands to that domain.
    ja, jb, a, b = pairs
    ja = (ja | 128)
    jb = (jb | 128)
    a, b = a | 128, b | 128
    full = np.asarray(approx_mul_uint(ja, jb, 8, variant,
                                      msb_always_set=True))
    assert (full <= a * b).all(), "approx must never exceed exact"
    if variant.truncated:
        assert (full & 0xFF).max() == 0, "truncated: low columns must be 0"
        base = np.asarray(approx_mul_uint(ja, jb, 8, variant.base,
                                          msb_always_set=True))
        if variant.base is not Variant.HLA:
            np.testing.assert_array_equal(full, base & (0xFF << 8))


@pytest.mark.parametrize("variant", VARIANTS)
def test_planes_consistent_with_single_word(pairs, variant):
    ja, jb, *_ = pairs
    hi, lo = approx_mul_uint_planes(ja, jb, 8, variant, msb_always_set=True)
    single = np.asarray(approx_mul_uint(ja, jb, 8, variant,
                                        msb_always_set=True))
    np.testing.assert_array_equal(np.asarray(hi) * 256 + np.asarray(lo),
                                  single)


def test_exact_mul_planes_n24_vs_int64():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 1 << 24, (2000,)).astype(np.int64)
    b = rng.integers(0, 1 << 24, (2000,)).astype(np.int64)
    hi, lo = exact_mul_planes(jnp.asarray(a, jnp.int32),
                              jnp.asarray(b, jnp.int32), 24)
    recon = np.asarray(hi, np.int64) << 24 | np.asarray(lo, np.int64)
    np.testing.assert_array_equal(recon, a * b)


def test_pc2_integer_drops_lsb_line():
    """Fig 3: integer PC2 sacrifices the H line => b bit0 contributes 0."""
    a = jnp.asarray([255], jnp.int32)
    one = jnp.asarray([1], jnp.int32)
    got = approx_mul_uint(a, one, 8, Variant.PC2, integer_drop_lsb=True)
    assert int(got[0]) == 0  # only b_0 set, line dropped
    kept = approx_mul_uint(a, one, 8, Variant.PC2, integer_drop_lsb=False)
    assert int(kept[0]) == 255


def test_pc_head_lines_are_exact():
    """When only the top-k multiplier bits are set, PC-k equals exact."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 256, (500,)), jnp.int32)
    for variant, topbits in ((Variant.PC2, 0b11000000),
                             (Variant.PC3, 0b11100000)):
        b = jnp.full_like(a, topbits)
        got = np.asarray(approx_mul_uint(a, b, 8, variant))
        np.testing.assert_array_equal(got, np.asarray(a) * topbits)


def test_error_ordering_mantissa_region():
    """Paper Table 2 driver: FLA > PC2 > PC3 error in the float regime."""
    rng = np.random.default_rng(3)
    ma = jnp.asarray(rng.integers(128, 256, (5000,)), jnp.int32)
    mb = jnp.asarray(rng.integers(128, 256, (5000,)), jnp.int32)
    exact = np.asarray(ma) * np.asarray(mb)
    errs = {}
    for v in (Variant.FLA, Variant.HLA, Variant.PC2, Variant.PC3):
        approx = np.asarray(approx_mul_uint(ma, mb, 8, v,
                                            msb_always_set=True))
        errs[v] = np.abs(exact - approx).mean() / exact.mean()
    assert errs[Variant.PC3] < errs[Variant.PC2] < errs[Variant.FLA]
    assert errs[Variant.HLA] < errs[Variant.FLA]


def test_sign_magnitude():
    a = jnp.asarray([-5, 5, -5, 0], jnp.int32)
    b = jnp.asarray([3, -3, -3, -7], jnp.int32)
    got = np.asarray(approx_mul_int_signmag(a, b, 8, Variant.EXACT))
    np.testing.assert_array_equal(got, [-15, -15, 15, 0])


def test_error_distance_metric():
    ed = np.asarray(error_distance(jnp.asarray([10, 0]), jnp.asarray([8, 0])))
    np.testing.assert_allclose(ed, [0.2, 0.0])


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

u8 = st.integers(min_value=0, max_value=255)


@settings(max_examples=200, deadline=None)
@given(a=u8, b=u8)
def test_prop_fla_bounds(a, b):
    got = int(approx_mul_uint(jnp.int32(a), jnp.int32(b), 8, Variant.FLA))
    assert got <= a * b
    assert (a == 0 or b == 0) == (got == 0)
    # bit k of FLA set iff exists i+j=k with a_j & b_i (wired-OR semantics)
    expect = 0
    for i in range(8):
        if (b >> i) & 1:
            expect |= a << i
    assert got == expect


@settings(max_examples=200, deadline=None)
@given(a=u8, b=u8)
def test_prop_hla_exact_iff_no_cross_parity_carry(a, b):
    """HLA = OR(even) + OR(odd): exact whenever each parity class has at
    most one active line (no intra-read collisions)."""
    hla = int(approx_mul_uint(jnp.int32(a), jnp.int32(b), 8, Variant.HLA))
    even_bits = [i for i in range(0, 8, 2) if (b >> i) & 1]
    odd_bits = [i for i in range(1, 8, 2) if (b >> i) & 1]
    if len(even_bits) <= 1 and len(odd_bits) <= 1:
        assert hla == a * b


@settings(max_examples=100, deadline=None)
@given(a=st.integers(0, (1 << 24) - 1), b=st.integers(0, (1 << 24) - 1),
       v=st.sampled_from(VARIANTS))
def test_prop_planes_n24_bounds(a, b, v):
    a |= 1 << 23  # mantissa domain (float mode: MSBs set)
    b |= 1 << 23
    hi, lo = approx_mul_uint_planes(jnp.int32(a), jnp.int32(b), 24, v,
                                    msb_always_set=True)
    got = (int(hi) << 24) | int(lo)
    assert 0 <= got <= a * b


def test_eq3_shift_normalization_fixes_small_multipliers():
    """Paper Eq. (3), implemented beyond-paper: pre-shifting small
    multipliers into the MSB-active region recovers PC2/PC3 accuracy."""
    from repro.core.multiplier import approx_mul_uint_normalized

    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.integers(1, 256, (3000,)), jnp.int32)
    b = jnp.asarray(rng.integers(1, 32, (3000,)), jnp.int32)  # small
    exact = np.asarray(a) * np.asarray(b)
    for v in (Variant.PC2, Variant.PC3):
        plain = np.asarray(approx_mul_uint(a, b, 8, v))
        normd = np.asarray(approx_mul_uint_normalized(a, b, 8, v))
        assert (normd <= exact).all()
        e_p = np.abs(exact - plain).mean()
        e_n = np.abs(exact - normd).mean()
        assert e_n < 0.6 * e_p, (v, e_p, e_n)
    # zero multiplier stays zero; exact single-bit cases stay exact
    z = approx_mul_uint_normalized(jnp.int32(200), jnp.int32(0), 8,
                                   Variant.PC3)
    assert int(z) == 0
    one = approx_mul_uint_normalized(jnp.int32(200), jnp.int32(4), 8,
                                     Variant.PC3)
    assert int(one) == 800  # b=4 -> single active (shifted A) line: exact
