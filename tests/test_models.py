"""Per-arch smoke tests: reduced configs, forward/train/decode on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import Backend, DaismConfig, Variant
from repro.models.registry import build_model, lm_loss

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=8):
    batch = {"tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (b, cfg.n_image_tokens, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((b, cfg.enc_frames, cfg.d_model),
                                    cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_decode(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params, axes = model.init(RNG)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 8, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    cache = model.init_cache(2, 16)
    tok = batch["tokens"][:, :1]
    if cfg.family == "vlm":
        dlogits, cache2 = model.decode_step(params, tok, cache,
                                            image_embeds=batch["image_embeds"])
    else:
        dlogits, cache2 = model.decode_step(params, tok, cache)
    assert dlogits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(dlogits.astype(jnp.float32)).all())
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_decreases_loss_direction(arch):
    """One SGD step along the gradient must not increase loss."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = _batch(cfg)
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)

    def loss_fn(p):
        logits, aux = model.forward(p, batch)
        return lm_loss(logits, labels, aux)

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0.0
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - 1e-2 * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    l1 = loss_fn(params2)
    assert float(l1) <= float(l0) + 1e-3


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "xlstm_1_3b",
                                  "zamba2_1_2b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_config(arch).smoke()
    if cfg.window:  # ring caches change masking only beyond the window
        cfg = dataclasses.replace(cfg, window=0)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab)
    full_logits, _ = model.forward(params, {"tokens": toks})

    cache = model.init_cache(1, 8)
    outs = []
    for t in range(6):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    ref = np.asarray(full_logits, np.float32)
    np.testing.assert_allclose(dec, ref, rtol=2e-2, atol=2e-2)


def test_full_size_param_counts():
    """Abstract init must reproduce the published parameter counts."""
    expected = {
        "tinyllama_1_1b": 1.10, "gemma_2b": 2.51, "starcoder2_15b": 15.96,
        "nemotron_4_340b": 341.0, "dbrx_132b": 131.6,
        "qwen3_moe_235b": 235.1, "llama_3_2_vision_11b": 11.5,
        "xlstm_1_3b": 1.06, "whisper_large_v3": 1.60, "zamba2_1_2b": 1.19,
    }
    for arch, want_b in expected.items():
        cfg = get_config(arch)
        shapes, _ = build_model(cfg).init(RNG, abstract=True)
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(shapes)) / 1e9
        assert abs(n - want_b) / want_b < 0.02, (arch, n, want_b)


def test_daism_mode_end_to_end():
    """The paper's technique as a first-class feature: tinyllama forward
    with PC3_tr numerics stays finite and close to the exact forward."""
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = _batch(cfg)
    exact, _ = model.forward(params, batch)

    daism = DaismConfig(variant=Variant.PC3_TR, backend=Backend.JNP)
    cfg2 = dataclasses.replace(cfg, daism=daism)
    model2 = build_model(cfg2)
    approx, _ = model2.forward(params, batch)
    e = np.asarray(exact, np.float32)
    a = np.asarray(approx, np.float32)
    assert np.isfinite(a).all()
    # logits correlate strongly despite ~5% per-product error
    corr = np.corrcoef(e.ravel(), a.ravel())[0, 1]
    assert corr > 0.95


def test_window_ring_cache_masks_old_tokens():
    cfg = get_config("zamba2_1_2b").smoke(window=4, n_layers=2,
                                          shared_attn_every=2)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    cache = model.init_cache(1, 16)
    tok = jnp.zeros((1, 1), jnp.int32)
    for _ in range(8):  # run past the window; must stay finite
        lg, cache = model.decode_step(params, tok, cache)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())
    assert int(cache["pos"]) == 8
