"""Float multiply + bit (de)composition tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev extra")
from hypothesis import given, settings, strategies as st

from repro.core import Variant, approx_mul_to_f32
from repro.core.bitops import (compose_bf16, compose_f32, decompose_bf16,
                               decompose_f32)
from repro.core.lut import approx_mul_to_f32_lut

VARIANTS = [Variant.FLA, Variant.HLA, Variant.PC2, Variant.PC3,
            Variant.PC2_TR, Variant.PC3_TR]


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5000,)) * np.exp(rng.normal(size=(5000,)) * 3)
    w = rng.normal(size=(5000,)) * np.exp(rng.normal(size=(5000,)) * 3)
    return x, w


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("variant", VARIANTS)
def test_magnitude_bound_and_sign(operands, dtype, variant):
    x = jnp.asarray(operands[0], dtype)
    w = jnp.asarray(operands[1], dtype)
    exact = np.asarray(x.astype(jnp.float32) * w.astype(jnp.float32))
    ap = np.asarray(approx_mul_to_f32(x, w, variant))
    assert (np.abs(ap) <= np.abs(exact) * (1 + 1e-6)).all()
    nz = (ap != 0)
    assert (np.sign(ap[nz]) == np.sign(exact[nz])).all()
    # bounded relative error (paper: worst case < 50% for FLA)
    rel = np.abs(exact - ap) / np.maximum(np.abs(exact), 1e-30)
    assert rel.max() < 0.51


def test_zero_handling():
    for dtype in (jnp.bfloat16, jnp.float32):
        z = jnp.zeros((4,), dtype)
        w = jnp.asarray([1.5, -2.0, 3.0, 1e10], dtype)
        for variant in (Variant.FLA, Variant.PC3_TR):
            out = np.asarray(approx_mul_to_f32(z, w, variant))
            np.testing.assert_array_equal(out, 0.0)
            out = np.asarray(approx_mul_to_f32(w, z, variant))
            np.testing.assert_array_equal(out, 0.0)


def test_subnormal_flush():
    tiny = jnp.asarray([1e-42], jnp.float32)  # subnormal f32
    w = jnp.asarray([2.0], jnp.float32)
    out = np.asarray(approx_mul_to_f32(tiny, w, Variant.PC3))
    np.testing.assert_array_equal(out, 0.0)


def test_lut_bit_identical(operands):
    x = jnp.asarray(operands[0], jnp.bfloat16)
    w = jnp.asarray(operands[1], jnp.bfloat16)
    for variant in VARIANTS:
        a = np.asarray(approx_mul_to_f32(x, w, variant))
        b = np.asarray(approx_mul_to_f32_lut(x, w, variant))
        np.testing.assert_array_equal(a, b)


def test_exact_variant_is_exact(operands):
    x = jnp.asarray(operands[0], jnp.bfloat16)
    w = jnp.asarray(operands[1], jnp.bfloat16)
    got = np.asarray(approx_mul_to_f32(x, w, Variant.EXACT))
    ref = np.asarray(x.astype(jnp.float32) * w.astype(jnp.float32))
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=300, deadline=None)
@given(bits=st.integers(0, 0xFFFF))
def test_prop_bf16_roundtrip(bits):
    x = jax.lax.bitcast_convert_type(jnp.uint16(bits), jnp.bfloat16)
    s, e, m = decompose_bf16(x)
    y = compose_bf16(s, e, m)
    xf = float(x.astype(jnp.float32))
    yf = float(y.astype(jnp.float32))
    if np.isnan(xf):
        return  # NaN mantissa payloads are not preserved (flushed path)
    if 0 < int(e):  # normal numbers round-trip exactly (inf included)
        assert xf == yf or (np.isinf(xf) and np.isinf(yf))
    else:  # subnormals flush to (signed) zero
        assert yf == 0.0


@settings(max_examples=300, deadline=None)
@given(bits=st.integers(0, 0xFFFFFFFF))
def test_prop_f32_roundtrip(bits):
    x = jax.lax.bitcast_convert_type(jnp.uint32(bits), jnp.float32)
    s, e, m = decompose_f32(x)
    y = compose_f32(s, e, m)
    xf, yf = float(x), float(y)
    if np.isnan(xf):
        return
    if 0 < int(e):
        assert xf == yf or (np.isinf(xf) and np.isinf(yf))
    else:
        assert yf == 0.0


@settings(max_examples=200, deadline=None)
@given(xs=st.floats(allow_nan=False, allow_infinity=False, width=32),
       ws=st.floats(allow_nan=False, allow_infinity=False, width=32),
       v=st.sampled_from(VARIANTS))
def test_prop_float_mul_invariants(xs, ws, v):
    x = jnp.float32(xs)
    w = jnp.float32(ws)
    ap = float(approx_mul_to_f32(x, w, v))
    exact = float(x * w)  # f32 semantics (overflow -> inf, like hardware)
    if np.isinf(exact) or exact == 0:
        return
    assert abs(ap) <= abs(exact) * (1 + 1e-6)
    if ap != 0:
        assert np.sign(ap) == np.sign(exact)
        assert abs(exact - ap) / abs(exact) < 0.51
