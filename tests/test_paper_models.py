"""Energy / cycle / area model tests (paper Fig 7-9 + headline claims)."""
from repro.core import Variant
from repro.core import arch_model as A
from repro.core import energy as E


def test_concurrent_mults_match_paper_numbers():
    """Paper §5.2.2: 32 kB/512-bit bank, bf16 => 32 truncated / 16 full."""
    assert E.concurrent_mults("bfloat16", True, 512) == 32
    assert E.concurrent_mults("bfloat16", False, 512) == 16


def test_active_wordlines_match_paper():
    """Paper §5.2.1: PC2_tr bf16 => at most 7 active wordlines."""
    assert E.active_wordlines(Variant.PC2_TR, "bfloat16") == 7
    assert E.active_wordlines(Variant.PC3_TR, "bfloat16") == 6
    assert E.active_wordlines(Variant.FLA, "bfloat16") == 8


def test_fig7_observations():
    base = E.total(E.eyeriss_energy_per_mult("bfloat16", truncated=True))
    hla = E.total(E.daism_energy_per_mult(Variant.HLA, "bfloat16",
                                          bank_kb=32, bus_bits=512))
    pc3t = E.total(E.daism_energy_per_mult(Variant.PC3_TR, "bfloat16",
                                           bank_kb=32, bus_bits=512))
    pc3 = E.total(E.daism_energy_per_mult(Variant.PC3, "bfloat16",
                                          bank_kb=32, bus_bits=512))
    pc2t = E.total(E.daism_energy_per_mult(Variant.PC2_TR, "bfloat16",
                                           bank_kb=32, bus_bits=512))
    assert hla >= base                      # observation 3: HLA not viable
    assert pc3t < base                      # DAISM wins
    assert pc3t < 0.6 * pc3                 # truncation ~2x ops per read
    assert pc3t < pc2t                      # PC3 fewer active wordlines


def test_fig9_geometry():
    layer = A.ConvLayer()
    assert layer.inputs == 150_528          # paper: VGG-8 L1 inputs
    assert layer.kernel_elements == 1_728   # paper: kernel elements
    ey = A.eyeriss_cycles(layer)["cycles"]
    res = {(b.num_banks, b.bank_kbytes): A.daism_cycles(layer, b)["cycles"]
           for b in A.FIG9_CONFIGS}
    assert res[(1, 512)] > max(res[(4, 128)], res[(16, 32)], res[(16, 8)])
    assert res[(16, 8)] == res[(4, 128)]    # paper §5.3.2 observation
    assert res[(16, 32)] < ey               # banked DAISM beats Eyeriss
    d = A.daism_cycles(layer, A.BankConfig(16, 32))
    assert d["pe_equivalent"] == 512        # paper: "512 processing elements"


def test_headline_direction():
    """-25% energy / -43% cycles (paper) — our constants must reproduce the
    sign and beat the claimed magnitudes' floor at comparable area."""
    layer = A.ConvLayer()
    ey_cycles = A.eyeriss_cycles(layer)["cycles"]
    ey_energy = A.eyeriss_layer_energy_uj(layer)
    bc = A.BankConfig(16, 8)                # smaller area than Eyeriss
    assert A.daism_area_mm2(bc) < A.eyeriss_area_mm2()
    cyc = A.daism_cycles(layer, bc)["cycles"]
    en = A.daism_layer_energy_uj(layer, bc)
    assert (ey_cycles - cyc) / ey_cycles > 0.25
    assert (ey_energy - en) / ey_energy > 0.25


def test_capacity_refills():
    """A kernel bigger than all banks triggers reload passes."""
    big = A.ConvLayer(h=14, w=14, cin=512, cout=512)  # 2.36M elements
    small_banks = A.BankConfig(1, 8)
    d = A.daism_cycles(big, small_banks)
    assert d["refills"] > 1
