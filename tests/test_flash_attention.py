"""Flash-attention Pallas kernel vs naive softmax + production attend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.models.layers import attend


def _naive(q, k, v, causal=True):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    k = jnp.repeat(k, h // kh, axis=2)
    v = jnp.repeat(v, h // kh, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = np.tril(np.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


SHAPES = [  # (B, Sq, Skv, H, KH, D) — GQA/MQA, ragged, multi-tile
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 2, 2, 128),
    (2, 100, 100, 4, 1, 32),   # ragged -> pad path
    (1, 64, 64, 8, 8, 16),     # MHA
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_matches_naive(shape, dtype):
    b, sq, skv, h, kh, d = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, kh, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, kh, d)), dtype)
    out = flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
    ref = _naive(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_flash_matches_production_attend():
    """Same numbers as the jnp online-softmax path used by the models."""
    rng = np.random.default_rng(1)
    b, s, h, kh, d = 2, 128, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.bfloat16)
    pos = jnp.arange(s)
    prod = attend(q, k, v, pos, pos, causal=True, chunk=64)
    flash = flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(prod, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_block_shape_invariance():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    outs = [np.asarray(flash_attention_bhsd(q, k, v, block_q=bq, block_k=bk),
                       np.float32)
            for bq, bk in [(64, 64), (128, 128), (64, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-2, atol=2e-3)
