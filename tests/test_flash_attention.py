"""Flash-attention Pallas kernel vs naive softmax + production attend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import Variant
from repro.kernels.flash_attention import flash_attention, flash_attention_bhsd
from repro.kernels.ref import daism_matmul_ref
from repro.models.layers import attend


def _naive(q, k, v, causal=True):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    k = jnp.repeat(k, h // kh, axis=2)
    v = jnp.repeat(v, h // kh, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = np.tril(np.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


SHAPES = [  # (B, Sq, Skv, H, KH, D) — GQA/MQA, ragged, multi-tile
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 2, 2, 128),
    (2, 100, 100, 4, 1, 32),   # ragged -> pad path
    (1, 64, 64, 8, 8, 16),     # MHA
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_matches_naive(shape, dtype):
    b, sq, skv, h, kh, d = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, kh, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, kh, d)), dtype)
    out = flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
    ref = _naive(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_flash_matches_production_attend():
    """Same numbers as the jnp online-softmax path used by the models."""
    rng = np.random.default_rng(1)
    b, s, h, kh, d = 2, 128, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.bfloat16)
    pos = jnp.arange(s)
    prod = attend(q, k, v, pos, pos, causal=True, chunk=64)
    flash = flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(prod, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_block_shape_invariance():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    outs = [np.asarray(flash_attention_bhsd(q, k, v, block_q=bq, block_k=bk),
                       np.float32)
            for bq, bk in [(64, 64), (128, 128), (64, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-2, atol=2e-3)


def test_fully_masked_causal_tiles():
    """Small KV blocks make whole (bq, bk) tiles causally masked (q tile 0 x
    every later kv tile): they must contribute nothing, not NaN/garbage."""
    rng = np.random.default_rng(3)
    b, s, h, d = 2, 128, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    out = flash_attention_bhsd(q, k, v, causal=True, block_q=32, block_k=32)
    assert not np.isnan(np.asarray(out, np.float32)).any()
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(_naive(q, k, v), np.float32),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("shape", [
    (2, 100, 72, 4, 2, 64),   # both lengths ragged, cross-shaped
    (1, 64, 130, 2, 1, 32),   # Skv > Sq, 2 ragged kv tiles
])
def test_padded_non_causal(shape):
    """causal=False with non-multiple-of-block lengths: padded keys must be
    masked via kv_len (an earlier revision asserted instead of masking)."""
    b, sq, skv, h, kh, d = shape
    rng = np.random.default_rng(sum(shape))
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, skv, kh, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, skv, kh, d)), jnp.bfloat16)
    out = flash_attention_bhsd(q, k, v, causal=False, block_q=64, block_k=64)
    ref = _naive(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_gqa_head_repeat_matches_attend():
    """8 query heads over 2 KV heads: the kernel's jnp.repeat layout must
    agree with attend's broadcast-repeat for every head, not just head 0."""
    rng = np.random.default_rng(4)
    b, s, h, kh, d = 1, 128, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.bfloat16)
    pos = jnp.arange(s)
    prod = attend(q, k, v, pos, pos, causal=True, chunk=32)
    flash = flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(prod, np.float32),
                               rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# DAISM-approximate flash attention vs kernels/ref.py oracles
# ---------------------------------------------------------------------------

def _flash_semantics_oracle(q, k, v, variant, causal):
    """Single-KV-tile mirror of the fused kernel's math: approximate QK
    (kernels/ref.py), scale, mask, *unnormalized* exp weights cast to bf16,
    approximate PV, exact divide by the row sum. Bit-comparable to the
    kernel up to f32 accumulation order when Skv fits one KV tile."""
    bh, s, d = q.shape
    outs = []
    for i in range(bh):
        s_mat = daism_matmul_ref(q[i], k[i].T, variant) * (1.0 / np.sqrt(d))
        if causal:
            mask = np.tril(np.ones((s, s), bool))
            s_mat = jnp.where(mask, s_mat, -1e30)
        m = s_mat.max(-1, keepdims=True)
        p = jnp.exp(s_mat - m)
        if causal:
            p = jnp.where(mask, p, 0.0)
        pv = daism_matmul_ref(p.astype(jnp.bfloat16), v[i], variant)
        outs.append(pv / p.sum(-1, keepdims=True))
    return jnp.stack(outs)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("variant", [Variant.PC3_TR, Variant.FLA])
def test_flash_approx_matches_ref_single_tile(variant, causal):
    """QK/PV products through the fused kernel carry kernels/ref.py
    semantics: with one KV tile the only slack is f32 accumulation order
    (amplified once through exp), so the tolerance is tight."""
    rng = np.random.default_rng(5)
    bh, s, d = 2, 128, 64
    q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=causal, variant=variant,
                          block_q=128, block_k=128)
    ref = _flash_semantics_oracle(q, k, v, variant, causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


def test_flash_approx_near_naive_softmax_oracle():
    """Multi-tile approximate attention stays close to the composed oracle
    (ref products + naive softmax). The oracle normalizes p *before* the
    bf16 cast while the kernel divides by l after the approximate PV — the
    approximate multiplier is not scale-invariant, so the comparison is
    loose; exactness per product is the single-tile test above."""
    rng = np.random.default_rng(6)
    bh, s, d = 2, 128, 64
    variant = Variant.PC3_TR
    q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, variant=variant,
                          block_q=64, block_k=64)
    outs = []
    for i in range(bh):
        s_mat = daism_matmul_ref(q[i], k[i].T, variant) / np.sqrt(d)
        s_mat = jnp.where(np.tril(np.ones((s, s), bool)), s_mat, -1e30)
        p = jax.nn.softmax(s_mat, -1)
        outs.append(daism_matmul_ref(p.astype(jnp.bfloat16), v[i], variant))
    ref = jnp.stack(outs)
    exact = flash_attention(q, k, v, causal=True, block_q=64, block_k=64
                            ).astype(jnp.float32)
    err_vs_oracle = float(jnp.max(jnp.abs(out - ref)))
    # the approximate paths agree with each other far better than either
    # agrees with exact attention — the deviation is the variant, not a bug
    err_vs_exact = float(jnp.max(jnp.abs(jnp.asarray(ref) - exact)))
    assert err_vs_oracle <= max(0.25, 0.75 * err_vs_exact), \
        (err_vs_oracle, err_vs_exact)


def test_flash_approx_requires_bf16():
    q = jnp.ones((1, 128, 16), jnp.float32)
    with pytest.raises(ValueError, match="bfloat16-only"):
        flash_attention(q, q, q, variant=Variant.PC3_TR)
