"""Optimizer + schedule + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.optim import (AdamWConfig, apply_updates, cosine_with_warmup,
                         init_state, quantize_int8)
from repro.optim.grad_compress import compressed_psum


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0], jnp.float32)}
    opt = init_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, grad_clip=1e9)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, metrics = apply_updates(params, g, opt, cfg)
    assert float(loss(params)) < 1e-2
    assert int(opt.step) == 200


def test_grad_clip_controls_norm():
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = init_state(params)
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = apply_updates(params, g, opt, cfg)
    assert float(metrics["grad_norm"]) == 200.0  # reported pre-clip


def test_master_weights_preserve_precision():
    """bf16 params + f32 master: tiny updates must not be lost."""
    params = {"w": jnp.ones((1,), jnp.bfloat16)}
    opt = init_state(params)
    cfg = AdamWConfig(lr=1e-5, weight_decay=0.0)
    g = {"w": jnp.ones((1,), jnp.bfloat16)}
    for _ in range(50):
        params, opt, _ = apply_updates(params, g, opt, cfg)
    # master moved even though each step is below bf16 resolution
    assert float(opt.master["w"][0]) < 1.0 - 1e-4


def test_schedule_shapes():
    s = cosine_with_warmup(jnp.int32(0), warmup=10, total=100)
    assert float(s) == 0.0
    s = cosine_with_warmup(jnp.int32(10), warmup=10, total=100)
    assert abs(float(s) - 1.0) < 1e-6
    s_end = cosine_with_warmup(jnp.int32(100), warmup=10, total=100,
                               min_ratio=0.1)
    assert abs(float(s_end) - 0.1) < 1e-6


def test_quantize_int8_roundtrip_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)) * 1e-3, jnp.float32)
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = quantize_int8(g, scale)
    deq = np.asarray(q, np.float32) * float(scale)
    cos = np.dot(deq, np.asarray(g)) / (
        np.linalg.norm(deq) * np.linalg.norm(np.asarray(g)))
    assert cos > 0.999


def test_compressed_psum_modes_single_device():
    """With a single device axis the mean must equal the input (up to
    quantization error)."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(64,)),
                          jnp.float32)}
    for mode in ("none", "bf16", "int8"):
        out = shard_map(
            lambda t: compressed_psum(t, ("data",), mode=mode),
            mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),),
            out_specs=jax.sharding.PartitionSpec(), check_vma=False)(g)
        a, b = np.asarray(out["w"]), np.asarray(g["w"])
        cos = np.dot(a, b) / (np.linalg.norm(a) * np.linalg.norm(b))
        assert cos > 0.99, mode
