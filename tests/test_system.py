"""End-to-end system tests: training loop, fault tolerance, resume,
distributed execution (multi-device cases run in a subprocess so the main
pytest process keeps the default single-device environment)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import image_batches, lm_batches
from repro.models.registry import build_model, lm_loss
from repro.optim import AdamWConfig, apply_updates, init_state
from repro.runtime.fault_tolerance import (LoopState, SimulatedPreemption,
                                           TrainLoopConfig, run)


def _make_step(model, ocfg):
    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            logits, aux = model.forward(p, batch)
            return lm_loss(logits, batch["labels"], aux)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, m = apply_updates(params, grads, opt, ocfg)
        m["loss"] = loss
        return params, opt, m

    return step


def _batches(cfg, batch=8, seq=16):
    for b in lm_batches(cfg.vocab, batch, seq, seed=0):
        yield b


def test_training_reduces_loss():
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = _make_step(model, AdamWConfig(lr=3e-3))
    gen = _batches(cfg)
    losses = []
    for _ in range(30):
        b = next(gen)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_fault_tolerant_resume(tmp_path):
    """Kill training mid-run; restart must resume from the checkpoint and
    finish, with the step counter consistent."""
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=1, vocab=32)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = _make_step(model, AdamWConfig(lr=1e-3))
    loop_cfg = TrainLoopConfig(total_steps=20, ckpt_dir=str(tmp_path),
                               ckpt_every=5, log_every=100)

    def put(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    def bomb(step_no):
        if step_no == 12:
            raise SimulatedPreemption("host lost")

    with pytest.raises(SimulatedPreemption):
        run(loop_cfg, step, params, opt, _batches(cfg), put,
            fault_hook=bomb)
    # "new process": restart with FRESH init (must be overwritten by restore)
    params2, _ = model.init(jax.random.PRNGKey(42))
    opt2 = init_state(params2)
    p_out, o_out, state = run(loop_cfg, step, params2, opt2,
                              _batches(cfg), put)
    assert state.step == 20
    from repro.checkpoint import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_straggler_watchdog(tmp_path):
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=1, vocab=32)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step0 = _make_step(model, AdamWConfig(lr=1e-3))
    import time as _t
    calls = {"n": 0}

    def slow_step(params, opt, batch):
        calls["n"] += 1
        if calls["n"] == 8:
            _t.sleep(2.0)  # inject a straggler step (>> smoke step time)
        return step0(params, opt, batch)

    loop_cfg = TrainLoopConfig(total_steps=10, ckpt_dir=str(tmp_path),
                               ckpt_every=100, straggler_factor=3.0)
    _, _, state = run(loop_cfg, slow_step, params, opt, _batches(cfg),
                      lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    assert state.stragglers >= 1


_DISTRIBUTED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh, best_effort_mesh
    from repro.launch.steps import build_artifacts
    from repro.data.synthetic import lm_batches, shard_batch
    from repro.checkpoint import checkpoint as ckpt

    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    art = build_artifacts(cfg, mesh, total_steps=40, warmup=2)
    params = art.init_params(jax.random.PRNGKey(0))
    opt = art.init_opt(params)
    gen = lm_batches(cfg.vocab, 8, 16, seed=0)
    bsh = art.batch_sharding(next(gen))
    losses = []
    for i in range(15):
        batch = shard_batch(next(gen), bsh)
        params, opt, m = art.train_step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
    ckpt.save("{ckpt}", 15, {{"params": params}})

    # elastic restore: same checkpoint onto a DIFFERENT mesh (2x2 subset)
    mesh2 = make_mesh((2, 2), ("data", "model"))
    art2 = build_artifacts(cfg, mesh2)
    restored = ckpt.restore("{ckpt}", 15, {{"params": art2.param_shapes}},
                            {{"params": art2.param_shardings}})["params"]
    l1 = jax.tree.leaves(params)[0]
    l2 = jax.tree.leaves(restored)[0]
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    print("DISTRIBUTED-OK")
""")


@pytest.mark.slow
def test_distributed_train_and_elastic_restore(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    script = _DISTRIBUTED_SCRIPT.format(ckpt=str(tmp_path))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "DISTRIBUTED-OK" in out.stdout, out.stderr[-3000:]
