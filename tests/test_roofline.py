"""Roofline methodology tests: the scan-undercount problem, the jaxpr FLOP
counter, the HLO collective parser, and the probe-correction method
validated against fully-unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.roofline import analysis as ra
from repro.roofline.flops import count_flops


def _scan_mm(unroll=1):
    def body(x, w):
        return jnp.dot(x, w), None

    return lambda x, w: lax.scan(body, x, w, unroll=unroll)[0]


def test_xla_counts_scan_body_once():
    """The motivating defect: cost_analysis under-reports scanned layers."""
    w = jnp.zeros((8, 128, 128), jnp.bfloat16)
    x = jnp.zeros((128, 128), jnp.bfloat16)
    cs = ra.xla_cost(jax.jit(_scan_mm()).lower(x, w).compile())
    cu = ra.xla_cost(jax.jit(_scan_mm(unroll=8)).lower(x, w).compile())
    assert float(cs["flops"]) < 0.2 * float(cu["flops"])


def test_jaxpr_flops_scan_equals_unrolled():
    w = jnp.zeros((8, 128, 128), jnp.bfloat16)
    x = jnp.zeros((128, 128), jnp.bfloat16)
    fs = count_flops(_scan_mm(), x, w)
    want = 8 * 2 * 128 ** 3
    assert abs(fs - want) / want < 0.01


def test_jaxpr_flops_grad_factor():
    w = jnp.zeros((8, 128, 128), jnp.bfloat16)
    x = jnp.zeros((128, 128), jnp.bfloat16)
    f = count_flops(_scan_mm(), x, w)
    g = count_flops(jax.grad(lambda x, w: (_scan_mm()(x, w) ** 2).sum(),
                             argnums=1), x, w)
    assert 2.8 < g / f < 3.3  # backward ~ 2x forward (+ fwd)


def test_jaxpr_flops_remat_recompute_counted():
    w = jnp.zeros((8, 2, 128, 128), jnp.bfloat16)
    x = jnp.zeros((128, 128), jnp.bfloat16)

    def mk(remat):
        def body(x, w):
            def f(x, w):
                return jnp.dot(jax.nn.relu(jnp.dot(x, w[0])), w[1])
            if remat:
                f = jax.checkpoint(f)
            return f(x, w), None
        return lambda x, w: (lax.scan(body, x, w)[0] ** 2).sum()

    f_plain = count_flops(jax.grad(mk(False), argnums=1), x, w)
    f_remat = count_flops(jax.grad(mk(True), argnums=1), x, w)
    assert f_remat > 1.1 * f_plain  # recompute visible


def test_collective_parser_on_hlo_text():
    hlo = """
  %ag = bf16[8,2048]{1,0} all-gather(bf16[8,128]{1,0} %x), replica_groups=[32,16]<=[512], dimensions={1}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %y), replica_groups={{0,1,2,3}}, to_apply=%add
  %a2a = bf16[16,64]{1,0} all-to-all(bf16[16,64]{1,0} %z), replica_groups=[2,16]<=[32]
  %cp = u32[4]{0} collective-permute(u32[4]{0} %w), source_target_pairs={{0,1}}
"""
    stats = ra.collective_bytes_from_hlo(hlo, default_group=8)
    # all-gather: 8*2048*2 bytes * (15/16)
    ag = 8 * 2048 * 2 * (15 / 16)
    ar = 1024 * 4 * 2 * (3 / 4)
    a2a = 16 * 64 * 2 * (15 / 16)
    cp = 4 * 4 * 1.0
    assert abs(stats.by_op["all-gather"] - ag) < 1
    assert abs(stats.by_op["all-reduce"] - ar) < 1
    assert abs(stats.by_op["all-to-all"] - a2a) < 1
    assert abs(stats.by_op["collective-permute"] - cp) < 1
    assert stats.count == 4


def test_probe_correction_matches_full_unroll():
    """The dry-run's scan correction: bytes(corrected) must approximate the
    fully-unrolled compile's bytes within 15%."""
    L = 8

    def model(unroll):
        def body(x, w):
            h = jax.nn.relu(jnp.dot(x, w))
            return jnp.dot(h, w.T), None

        def f(x, w):
            return lax.scan(body, x, w, unroll=unroll)[0].sum()
        return f

    x = jnp.zeros((64, 256), jnp.bfloat16)
    w = jnp.zeros((L, 256, 256), jnp.bfloat16)

    def bytes_of(unroll):
        c = ra.xla_cost(jax.jit(model(unroll)).lower(x, w).compile())
        return float(c["bytes accessed"])

    b1, b2, bfull = bytes_of(1), bytes_of(2), bytes_of(L)
    corrected = b1 + (b2 - b1) * (L - 1) / (2 - 1)
    assert abs(corrected - bfull) / bfull < 0.15


def test_model_flops_estimate_moe_active_params():
    from repro.configs import get_config
    dense = ra.model_flops_estimate(get_config("tinyllama_1_1b"), "train",
                                    4096, 256)
    # 6 * 1.1e9 * (4096*256)
    want = 6 * 1.10e9 * 4096 * 256
    assert abs(dense - want) / want < 0.05
    moe = ra.model_flops_estimate(get_config("qwen3_moe_235b"), "train",
                                  4096, 256)
    # active ~22B of 235B
    want_moe = 6 * 22.5e9 * 4096 * 256
    assert abs(moe - want_moe) / want_moe < 0.15
