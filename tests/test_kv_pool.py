"""BlockPool unit tests: alloc/extend/free, ref-counting, prefix caching,
eviction, and fragmentation accounting (pure Python, no jax)."""
import pytest

from repro.serve import BlockPool, blocks_needed


def test_blocks_needed():
    assert blocks_needed(0, 4) == 0
    assert blocks_needed(1, 4) == 1
    assert blocks_needed(4, 4) == 1
    assert blocks_needed(5, 4) == 2


def test_allocate_and_free_roundtrip():
    pool = BlockPool(num_blocks=4, block_size=4)
    table, cached = pool.allocate("a", prompt=[1, 2, 3], total_len=6)
    assert cached == 0 and len(table) == 2  # ceil(6/4)
    assert pool.blocks_in_use == 2 and pool.blocks_available == 2
    pool.free("a")
    assert pool.blocks_in_use == 0 and pool.blocks_available == 4


def test_allocation_refused_when_full_no_partial_state():
    pool = BlockPool(num_blocks=2, block_size=4)
    assert pool.allocate("a", [1] * 4, total_len=8) is not None
    before = pool.stats()
    assert pool.allocate("b", [2] * 4, total_len=8) is None
    assert pool.stats() == before  # refusal must not leak blocks
    pool.free("a")
    assert pool.allocate("b", [2] * 4, total_len=8) is not None


def test_double_free_raises():
    pool = BlockPool(num_blocks=2, block_size=4)
    pool.allocate("a", [1, 2], total_len=2)
    pool.free("a")
    with pytest.raises(KeyError, match="double free"):
        pool.free("a")


def test_extend_grows_and_respects_capacity():
    pool = BlockPool(num_blocks=3, block_size=4)
    table, _ = pool.allocate("a", [1, 2], total_len=2)
    assert len(table) == 1
    assert len(pool.extend("a", 8)) == 2
    assert pool.extend("a", 8) is not None  # idempotent at same length
    assert pool.extend("a", 100) is None    # beyond capacity -> refused
    pool.free("a")
    assert pool.blocks_available == 3  # extended blocks freed too


def test_prefix_sharing_refcounts_and_no_double_release():
    pool = BlockPool(num_blocks=8, block_size=4)
    prompt = list(range(10))  # blocks 0-1 full, block 2 partial
    t_a, cached_a = pool.allocate("a", prompt, total_len=12, policy_key="p")
    assert cached_a == 0
    pool.commit_prefix("a")
    t_b, cached_b = pool.allocate("b", prompt, total_len=12, policy_key="p")
    assert cached_b == 8  # two full prompt blocks adopted
    assert t_b[:2] == t_a[:2] and t_b[2] != t_a[2]  # boundary not shared
    assert pool.prefix_hits == 2
    used = pool.blocks_in_use
    pool.free("a")  # shared blocks stay live under b's refcount
    assert pool.blocks_in_use == used - 1  # only a's private block released
    pool.free("b")
    assert pool.blocks_in_use == 0
    # shared blocks are now evictable, not plain free: still hittable
    t_c, cached_c = pool.allocate("c", prompt, total_len=12, policy_key="p")
    assert cached_c == 8 and t_c[:2] == t_a[:2]


def test_prefix_cache_keyed_by_policy():
    pool = BlockPool(num_blocks=8, block_size=4)
    prompt = list(range(9))
    pool.allocate("free_req", prompt, total_len=9, policy_key="free")
    pool.commit_prefix("free_req")
    _, cached = pool.allocate("paid_req", prompt, total_len=9,
                              policy_key="paid")
    assert cached == 0  # approximate K/V must not leak into the exact tier
    _, cached = pool.allocate("free_req2", prompt, total_len=9,
                              policy_key="free")
    assert cached == 8


def test_prefix_never_covers_whole_prompt():
    """At least one prompt token must remain to prefill (first-token
    logits), even when every block of the prompt is cached."""
    pool = BlockPool(num_blocks=8, block_size=4)
    prompt = list(range(8))  # exactly two full blocks
    pool.allocate("a", prompt, total_len=8, policy_key=None)
    pool.commit_prefix("a")
    _, cached = pool.allocate("b", prompt, total_len=8, policy_key=None)
    assert cached == 4  # second block is NOT adopted: its tail is the last token


def test_uncommitted_blocks_are_not_shared():
    pool = BlockPool(num_blocks=8, block_size=4)
    prompt = list(range(9))
    pool.allocate("a", prompt, total_len=9, policy_key=None)
    # no commit_prefix: a's prefill has not written these blocks yet
    _, cached = pool.allocate("b", prompt, total_len=9, policy_key=None)
    assert cached == 0


def test_eviction_reclaims_lru_cached_blocks():
    pool = BlockPool(num_blocks=3, block_size=4)
    prompt = list(range(5))  # 1 full block + 1 partial
    pool.allocate("a", prompt, total_len=5, policy_key=None)
    pool.commit_prefix("a")
    pool.free("a")  # full block -> evictable, partial -> free list
    assert pool.stats()["blocks_evictable"] == 1
    # demand 3 blocks: the free list has 2, so the cached block is evicted
    t, cached = pool.allocate("b", [9, 9, 9], total_len=12, policy_key=None)
    assert len(t) == 3 and cached == 0
    assert pool.stats()["blocks_evictable"] == 0
    pool.free("b")
    # the evicted block's cache entry is gone: the old prompt misses now
    _, cached = pool.allocate("c", prompt, total_len=5, policy_key=None)
    assert cached == 0


def test_utilization_and_fragmentation_accounting():
    pool = BlockPool(num_blocks=4, block_size=4)
    pool.allocate("a", [1, 2, 3], total_len=8)  # 2 blocks reserved
    pool.advance("a", 3)  # only the prompt written so far
    u = pool.utilization()
    assert u["pool_util"] == pytest.approx(3 / 16)
    assert u["reserved_util"] == pytest.approx(8 / 16)
    assert u["internal_frag"] == pytest.approx(5 / 8)
    pool.advance("a", 8)
    assert pool.utilization()["internal_frag"] == 0.0


def test_invalid_construction():
    with pytest.raises(ValueError, match="num_blocks"):
        BlockPool(0, 4)
    with pytest.raises(ValueError, match="block_size"):
        BlockPool(4, 0)


# -- speculative rollback (truncate) ----------------------------------------


def test_truncate_frees_wholly_rejected_pages():
    pool = BlockPool(num_blocks=6, block_size=4)
    pool.allocate("a", [1, 2, 3], total_len=4)          # 1 page
    assert len(pool.extend("a", 12)) == 3               # spec lookahead
    # verify kept only up to position 5: page 3 covers [8,12) = all
    # rejected -> freed; page 2 covers [4,8) = partially kept -> stays
    assert pool.truncate("a", 6) == 1
    assert pool.blocks_in_use == 2 and pool.blocks_available == 4
    assert pool.truncate("a", 6) == 0                   # idempotent
    pool.free("a")
    assert pool.blocks_in_use == 0


def test_truncate_partial_page_kept_and_regrowable():
    pool = BlockPool(num_blocks=4, block_size=4)
    pool.allocate("a", [1, 2], total_len=2)
    pool.extend("a", 10)                                # 3 pages
    assert pool.truncate("a", 3) == 2                   # back to 1 page
    # the sequence can speculate again from the rolled-back state
    assert len(pool.extend("a", 10)) == 3
    pool.free("a")
    assert pool.blocks_available == 4


def test_truncate_never_cuts_prompt_or_shared_prefix():
    pool = BlockPool(num_blocks=8, block_size=4)
    prompt = list(range(10))                            # 3 pages
    pool.allocate("a", prompt, total_len=12, policy_key="p")
    pool.commit_prefix("a")
    t_b, cached = pool.allocate("b", prompt, total_len=12, policy_key="p")
    assert cached == 8                                  # 2 shared pages
    # keep_len 0 still may not release pages under b's prompt
    assert pool.truncate("b", 0) == 0
    assert len(pool.extend("b", 12)) == 3               # table regrowable
    pool.free("a")
    pool.free("b")
    # shared pages survived both lifecycles: next identical prompt hits
    _, cached = pool.allocate("c", prompt, total_len=12, policy_key="p")
    assert cached == 8


def test_truncate_negative_keep_len_raises():
    pool = BlockPool(num_blocks=2, block_size=4)
    pool.allocate("a", [1], total_len=2)
    with pytest.raises(ValueError, match="keep_len"):
        pool.truncate("a", -1)


def test_truncate_interleaved_with_preempt_swap_leaks_no_pages():
    """Property-style sweep: random interleavings of speculative extend ->
    partial-accept truncate -> preempt (free) -> resume (re-allocate) must
    keep the pool's page accounting exact — every page is free, evictable,
    or owned, after every operation — and drain to an empty pool."""
    import random

    rng = random.Random(7)
    for trial in range(30):
        num_blocks = rng.randint(4, 12)
        block_size = rng.choice([2, 4, 8])
        pool = BlockPool(num_blocks=num_blocks, block_size=block_size)
        live = {}    # seq_id -> committed length (what a scheduler tracks)
        prompts = {}
        next_id = 0
        for _ in range(60):
            s = pool.stats()
            assert (s["blocks_free"] + s["blocks_evictable"]
                    + s["blocks_in_use"] == num_blocks), (trial, s)
            op = rng.choice(["admit", "spec", "accept", "preempt", "retire"])
            if op == "admit":
                sid = f"t{trial}_s{next_id}"
                prompt = [rng.randrange(64) for _ in
                          range(rng.randint(1, 2 * block_size))]
                total = len(prompt) + rng.randint(1, 2 * block_size)
                if pool.allocate(sid, prompt, total,
                                 policy_key=sid) is not None:
                    next_id += 1
                    prompts[sid] = prompt
                    live[sid] = len(prompt)
                    pool.commit_prefix(sid)
            elif op == "spec" and live:
                sid = rng.choice(sorted(live))
                k = rng.randint(1, block_size)  # draft lookahead
                pool.extend(sid, live[sid] + 1 + k)  # None = best-effort miss
            elif op == "accept" and live:
                sid = rng.choice(sorted(live))
                live[sid] += rng.randint(0, block_size)  # n_acc + bonus
                pool.truncate(sid, live[sid])
            elif op == "preempt" and live:
                sid = rng.choice(sorted(live))
                pool.free(sid)  # K/V swapped to host by the engine
                # resume immediately if pages allow, else drop the request
                if pool.allocate(sid, prompts[sid],
                                 max(live[sid], len(prompts[sid]) + 1),
                                 policy_key=sid) is None:
                    del live[sid], prompts[sid]
            elif op == "retire" and live:
                sid = rng.choice(sorted(live))
                pool.free(sid)
                del live[sid], prompts[sid]
        for sid in sorted(live):
            pool.free(sid)
        assert pool.blocks_in_use == 0, trial
        assert pool.stats()["blocks_free"] \
            + pool.stats()["blocks_evictable"] == num_blocks
