"""Synthetic data pipeline tests: determinism + learnable structure."""
import numpy as np

from repro.data.synthetic import eval_set, image_batches, lm_batches


def test_lm_batches_deterministic():
    a = next(lm_batches(64, 4, 16, seed=7))
    b = next(lm_batches(64, 4, 16, seed=7))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_lm_labels_are_shifted_tokens():
    b = next(lm_batches(64, 4, 16, seed=0))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_lm_bigram_structure_exists():
    """The generator follows a 4-successor automaton 90% of the time: the
    empirical successor set per token must be far smaller than uniform."""
    gen = lm_batches(32, 16, 128, seed=3)
    succ = {t: set() for t in range(32)}
    for _ in range(5):
        b = next(gen)
        toks, labs = b["tokens"], b["labels"]
        for row_t, row_l in zip(toks, labs):
            for t, l in zip(row_t, row_l):
                succ[int(t)].add(int(l))
    sizes = [len(s) for s in succ.values() if s]
    assert np.mean(sizes) < 24  # uniform would approach 32


def test_image_batches_class_structure():
    gen = image_batches(10, 64, shape=(8, 8, 1), noise=0.1, seed=0)
    b = next(gen)
    assert b["images"].shape == (64, 8, 8, 1)
    # same-class images correlate more than cross-class
    imgs, labs = b["images"].reshape(64, -1), b["labels"]
    same, cross = [], []
    for i in range(30):
        for j in range(i + 1, 30):
            c = np.dot(imgs[i], imgs[j]) / (
                np.linalg.norm(imgs[i]) * np.linalg.norm(imgs[j]) + 1e-9)
            (same if labs[i] == labs[j] else cross).append(c)
    assert np.mean(same) > np.mean(cross) + 0.3


def test_eval_set_sizes():
    batches = eval_set(image_batches(10, 8, shape=(8, 8, 1)), 3)
    assert len(batches) == 3
    assert all(b["images"].shape[0] == 8 for b in batches)
