"""Tensor-parallel paged serving: 4-way sharded engine token identity.

Runs in subprocesses (the sharded engine needs 4 fake devices; the main
pytest process keeps the default single-device environment). Two claims:

* mixed-tier Poisson traffic served by the 4-shard engine is
  token-identical to the single-device engine (same EngineConfig), and
* a preempt/swap/resume cycle on the sharded engine is token-identical too
  — the page gather/scatter swap path crosses shards without corruption, and
* the full composition — 4-way sharded + preempting + self-speculative
  decode (cheap draft, exact batched verify) — still matches the plain
  single-device reserve engine token-for-token.

The smoke model runs f32 compute: the row-parallel output projections
psum partial sums in a different order per mesh size, which at bf16
(eps ~ 8e-3) is enough to flip near-tied argmaxes on a random toy model;
at f32 the reorder noise (~1e-6) is far below toy logit gaps.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.serve import (EngineConfig, Request, ServeEngine,
                             poisson_requests)

    assert jax.device_count() == 4, jax.devices()
    cfg = get_config("tinyllama_1_1b").smoke(
        n_layers=2, vocab=128, window=0, kv_heads=4,
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    TIERS = (("free", "*=pc3_tr"), ("paid", "*=exact"))

    def outputs(report):
        return {s.request_id: s.output for s in report.completed}

    def requests(seed):
        return poisson_requests(6, cfg.vocab, rate=0.5, base_prompt=7,
                                base_gen=10, seed=seed,
                                tiers=["free", "paid"])
""")

_SHARDED = _COMMON + textwrap.dedent("""
    base = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=48, block_size=8, prefill_chunk=8,
        tiers=TIERS))
    ref = outputs(base.run(requests(0)))

    mesh = jax.make_mesh((4,), ("model",))
    eng = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=48, block_size=8, prefill_chunk=8,
        tiers=TIERS, shards=4), mesh=mesh)
    rep = eng.run(requests(0))
    assert rep.shards == 4, rep.shards
    assert rep.policy_groups == 2, rep.policy_groups
    got = outputs(rep)
    assert got == ref, {k: (got[k], ref[k]) for k in got if got[k] != ref[k]}
    print("SHARDED-IDENTICAL-OK")
""")

_PREEMPT = _COMMON + textwrap.dedent("""
    reqs = poisson_requests(6, cfg.vocab, rate=1.0, base_prompt=7,
                            base_gen=14, seed=1, tiers=["free", "paid"])
    def fresh():
        return [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                        arrival_step=r.arrival_step, policy=r.policy)
                for r in reqs]
    base = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=48, block_size=8, prefill_chunk=8,
        tiers=TIERS))
    ref = outputs(base.run(fresh()))

    mesh = jax.make_mesh((4,), ("model",))
    # 8-page pool against rows growing to 3 pages each: exhaustion is
    # guaranteed under concurrent decode, so the swap path really runs
    eng = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=48, block_size=8, num_blocks=8,
        prefill_chunk=8, tiers=TIERS, shards=4, preempt=True), mesh=mesh)
    rep = eng.run(fresh())
    assert rep.preemptions >= 1, "pool never exhausted; shrink it"
    assert rep.resumes == rep.preemptions
    got = outputs(rep)
    assert got == ref, {k: (got[k], ref[k]) for k in got if got[k] != ref[k]}
    print("SHARDED-PREEMPT-OK", rep.preemptions, rep.resumes)
""")

_SPEC = _COMMON + textwrap.dedent("""
    reqs = poisson_requests(6, cfg.vocab, rate=1.0, base_prompt=7,
                            base_gen=14, seed=1, tiers=["free", "paid"])
    def fresh():
        return [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                        arrival_step=r.arrival_step, policy=r.policy)
                for r in reqs]
    base = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=48, block_size=8, prefill_chunk=8,
        tiers=TIERS))
    ref = outputs(base.run(fresh()))

    mesh = jax.make_mesh((4,), ("model",))
    # sharded + preempting + speculative: the draft chain, batched verify,
    # page rollback, and swap path all cross the 4-way mesh together
    eng = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=48, block_size=8, num_blocks=8,
        prefill_chunk=8, tiers=TIERS, shards=4, preempt=True,
        spec_draft="*=pc3_tr", spec_k=3), mesh=mesh)
    rep = eng.run(fresh())
    assert rep.shards == 4, rep.shards
    assert rep.spec_steps >= 1, "speculation never ran"
    assert rep.preemptions >= 1, "pool never exhausted; shrink it"
    got = outputs(rep)
    assert got == ref, {k: (got[k], ref[k]) for k in got if got[k] != ref[k]}
    stats = eng.pool.stats()
    assert stats["blocks_in_use"] == 0, stats
    print("SHARDED-SPEC-PREEMPT-OK", rep.spec_steps,
          round(rep.spec_tokens_per_step, 2))
""")

_MISMATCH = _COMMON + textwrap.dedent("""
    mesh = jax.make_mesh((4,), ("model",))
    try:
        ServeEngine(model, params, EngineConfig(
            num_slots=3, max_seq=48, block_size=8, prefill_chunk=8,
            shards=4), mesh=mesh)
    except ValueError as e:
        assert "divisible" in str(e) and "SRV007" in str(e), e
        print("DIVISIBILITY-REJECTED-OK")
""")


def _run(script):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560)


@pytest.mark.slow
def test_sharded_engine_token_identical_mixed_tier_poisson():
    out = _run(_SHARDED)
    assert "SHARDED-IDENTICAL-OK" in out.stdout, out.stderr[-3000:]


@pytest.mark.slow
def test_sharded_engine_preempt_resume_token_identical():
    out = _run(_PREEMPT)
    assert "SHARDED-PREEMPT-OK" in out.stdout, out.stderr[-3000:]


@pytest.mark.slow
def test_sharded_engine_spec_preempt_token_identical():
    out = _run(_SPEC)
    assert "SHARDED-SPEC-PREEMPT-OK" in out.stdout, out.stderr[-3000:]


@pytest.mark.slow
def test_sharded_engine_rejects_indivisible_layout():
    out = _run(_MISMATCH)
    assert "DIVISIBILITY-REJECTED-OK" in out.stdout, out.stderr[-3000:]
