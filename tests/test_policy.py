"""Per-site approximation policy API: rules, segmentation, dispatch, models."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import policy as P
from repro.configs import get_config
from repro.core import Backend, DaismConfig, Variant
from repro.models.registry import build_model

RNG = jax.random.PRNGKey(0)
PC3_TR = DaismConfig(variant=Variant.PC3_TR, backend=Backend.JNP)
FLA = DaismConfig(variant=Variant.FLA, backend=Backend.JNP)




# ---------------------------------------------------------------------------
# Rule precedence / parsing
# ---------------------------------------------------------------------------

def test_first_match_wins_and_default_fallback():
    pol = P.ApproxPolicy(rules=(
        P.Rule("*/attn/*", P.EXACT),
        P.Rule("*/attn/wq", FLA),      # shadowed: the broader rule is first
        P.Rule("*/ffn/*", FLA),
    ), default=PC3_TR)
    assert pol.resolve("decoder/layer_0/attn/wq") is P.EXACT
    assert pol.resolve("decoder/layer_1/ffn/wi") is FLA
    assert pol.resolve("decoder/lm_head") is PC3_TR  # no rule -> default


def test_kind_pattern_and_kind_restriction():
    pol = P.ApproxPolicy(rules=(
        P.Rule("@lm_head", P.EXACT),
        P.Rule("*", FLA, kind=P.OpKind.CONV),
    ), default=PC3_TR)
    assert pol.resolve("decoder/lm_head", P.OpKind.LM_HEAD) is P.EXACT
    # same path, different kind: the @ rule must not fire
    assert pol.resolve("decoder/lm_head", P.OpKind.DENSE) is PC3_TR
    assert pol.resolve("cnn/c1", P.OpKind.CONV) is FLA
    assert pol.resolve("cnn/f1", P.OpKind.DENSE) is PC3_TR


def test_parse_policy_spec():
    pol = P.parse_policy("*/attn/*=exact,*/ffn/*=pc3_tr:lut,*=fla")
    assert pol.resolve("x/attn/wq").exact
    ffn = pol.resolve("x/ffn/wi")
    assert ffn.variant is Variant.PC3_TR and ffn.backend is Backend.LUT
    assert pol.resolve("anything/else").variant is Variant.FLA
    with pytest.raises(ValueError, match="unknown variant"):
        P.parse_policy("*=bogus")
    with pytest.raises(ValueError, match="unknown backend"):
        P.parse_policy("*=fla:bogus")
    with pytest.raises(ValueError, match="pattern=variant"):
        P.parse_policy("justapattern")


def test_parse_policy_catch_all_is_ordered():
    """A '*=' entry is a regular rule: written first it shadows later
    rules; 'default=' sets the fallback without entering the rule order."""
    pol = P.parse_policy("*=exact,*/ffn/*=pc3_tr")
    assert pol.resolve("x/ffn/wi").exact  # '*' fires first
    trailing = P.parse_policy("*/ffn/*=pc3_tr,*=fla")
    assert trailing.resolve("x/ffn/wi").variant is Variant.PC3_TR
    assert trailing.resolve("x/attn/wq").variant is Variant.FLA
    dflt = P.parse_policy("default=fla,*/ffn/*=pc3_tr")
    assert dflt.resolve("x/attn/wq").variant is Variant.FLA
    assert dflt.resolve("x/ffn/wi").variant is Variant.PC3_TR


def test_rule_precedence_property():
    """Property test: resolve() == first matching rule in order, else
    default — over randomized rule lists and paths."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    segs = st.sampled_from(["attn", "ffn", "wq", "wi", "layer_0", "layer_1"])
    path_st = st.lists(segs, min_size=1, max_size=4).map("/".join)
    pattern_st = st.one_of(
        path_st,
        st.lists(st.sampled_from(["*", "attn", "ffn", "layer_0"]),
                 min_size=1, max_size=3).map("/".join))
    cfg_st = st.sampled_from([P.EXACT, PC3_TR, FLA])
    rules_st = st.lists(st.tuples(pattern_st, cfg_st), max_size=5)

    @hyp.given(rules=rules_st, path=path_st)
    @hyp.settings(max_examples=200, deadline=None)
    def check(rules, path):
        pol = P.ApproxPolicy(
            rules=tuple(P.Rule(p, c) for p, c in rules), default=PC3_TR)
        import fnmatch
        expected = PC3_TR
        for p, c in rules:
            if fnmatch.fnmatchcase(path, p):
                expected = c
                break
        assert pol.resolve(path) == expected

    check()


def test_policy_is_jit_static():
    pol = P.ApproxPolicy.first_last_exact(PC3_TR, 4)
    assert hash(pol) == hash(P.ApproxPolicy.first_last_exact(PC3_TR, 4))

    @functools.partial(jax.jit, static_argnums=(1,))
    def f(x, policy):
        return x * (0.0 if policy.resolve("a/b").exact else 1.0)

    assert float(f(jnp.ones(()), pol)) == 1.0


# ---------------------------------------------------------------------------
# Segmentation
# ---------------------------------------------------------------------------

def _sites(i):
    return [(f"decoder/layer_{i}/attn/wq", P.OpKind.DENSE),
            (f"decoder/layer_{i}/ffn/wi", P.OpKind.DENSE)]


def test_plan_segments_uniform_single_segment():
    pol = P.ApproxPolicy.uniform(PC3_TR)
    assert P.plan_segments(pol, _sites, 0, 6) == ((0, 6),)


def test_plan_segments_first_last_exact():
    pol = P.ApproxPolicy.first_last_exact(PC3_TR, 6)
    assert P.plan_segments(pol, _sites, 0, 6) == ((0, 1), (1, 5), (5, 6))


def test_plan_segments_depth_schedule():
    pol = P.ApproxPolicy.depth_schedule([P.EXACT, P.EXACT, PC3_TR, FLA])
    assert P.plan_segments(pol, _sites, 0, 4) == ((0, 2), (2, 3), (3, 4))


# ---------------------------------------------------------------------------
# Construction-time / resolve-time validation
# ---------------------------------------------------------------------------

def test_daism_config_construction_validation():
    with pytest.raises(ValueError, match="accum_dtype"):
        DaismConfig(accum_dtype="int32")
    with pytest.raises(ValueError, match="k_chunk"):
        DaismConfig(k_chunk=0)
    with pytest.raises(ValueError, match="block"):
        DaismConfig(block_m=0)
    with pytest.raises(ValueError, match="backward"):
        DaismConfig(backend=Backend.PALLAS, backward="approx")


def test_backend_dtype_validation_at_arch_construction():
    cfg = get_config("lenet5")  # float32 compute
    lut = DaismConfig(variant=Variant.PC3_TR, backend=Backend.LUT)
    with pytest.raises(ValueError, match="bfloat16-only"):
        dataclasses.replace(cfg, daism=lut)
    with pytest.raises(ValueError, match="bfloat16-only"):
        cfg.with_policy("cnn/c1=pc3_tr:pallas")
    # jnp backend supports float32: must construct fine
    cfg.with_policy("cnn/c1=pc3_tr")


def test_validate_for_dtype_names_site():
    lut = DaismConfig(variant=Variant.PC3_TR, backend=Backend.LUT)
    with pytest.raises(ValueError, match="decoder/layer_0/attn/wq"):
        P.validate_for_dtype(lut, jnp.float32,
                             site="decoder/layer_0/attn/wq")
    P.validate_for_dtype(lut, jnp.bfloat16)  # ok
    P.validate_for_dtype(P.EXACT, jnp.int8)  # exact: anything goes


# ---------------------------------------------------------------------------
# Kernel cache
# ---------------------------------------------------------------------------

def test_kernel_cache_no_retrace_for_same_config():
    cfg = DaismConfig(variant=Variant.PC2, backend=Backend.JNP, k_chunk=17)
    k1 = P.matmul_kernel(cfg)
    k2 = P.matmul_kernel(DaismConfig(variant=Variant.PC2,
                                     backend=Backend.JNP, k_chunk=17))
    assert k1 is k2  # equal configs share one jitted kernel
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(3, 17)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(17, 5)), jnp.bfloat16)
    t0 = P.kernel_stats()["kernel_traces"]
    o1 = k1(a, w)
    t1 = P.kernel_stats()["kernel_traces"]
    o2 = k2(a, w)
    assert P.kernel_stats()["kernel_traces"] == t1  # second call: cache hit
    assert t1 == t0 + 1
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_mixed_policy_shares_kernels_across_sites():
    """Two different sites resolving to the same config reuse one kernel;
    repeated forwards do not re-trace."""
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    pol = P.ApproxPolicy(rules=(P.Rule("*/attn/*", PC3_TR),
                                P.Rule("*/ffn/*", PC3_TR)),
                         default=P.EXACT)
    model = build_model(cfg.with_policy(pol))
    params, _ = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (1, 4), 0, cfg.vocab)}
    model.forward(params, batch)
    traces = P.kernel_stats()["kernel_traces"]
    model.forward(params, batch)  # same shapes, same resolved configs
    assert P.kernel_stats()["kernel_traces"] == traces


# ---------------------------------------------------------------------------
# End-to-end model behavior
# ---------------------------------------------------------------------------

def test_uniform_policy_matches_legacy_daism_shim():
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 6), 0, cfg.vocab)}

    legacy = build_model(dataclasses.replace(cfg, daism=PC3_TR))
    shim, _ = legacy.forward(params, batch)
    explicit = build_model(cfg.with_policy(P.ApproxPolicy.uniform(PC3_TR)))
    pol, _ = explicit.forward(params, batch)
    np.testing.assert_array_equal(np.asarray(shim, np.float32),
                                  np.asarray(pol, np.float32))


def test_all_exact_policy_matches_plain_exact():
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 6), 0, cfg.vocab)}
    ref, _ = model.forward(params, batch)
    pol, _ = build_model(
        cfg.with_policy("*=exact")).forward(params, batch)
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(pol, np.float32))


def test_mixed_policy_segments_and_fidelity():
    """first/last layer + lm_head exact must sit between all-exact and
    all-approx in logit fidelity, and the scan must split into 3 segments."""
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=64)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 6), 0, cfg.vocab)}
    exact, _ = model.forward(params, batch)

    mixed_pol = P.ApproxPolicy.first_last_exact(FLA, cfg.n_layers)
    mixed_model = build_model(cfg.with_policy(mixed_pol))
    assert mixed_model.segments == ((0, 1), (1, 3), (3, 4))
    mixed, _ = mixed_model.forward(params, batch)
    uniform, _ = build_model(
        cfg.with_policy(P.ApproxPolicy.uniform(FLA))).forward(params, batch)

    e = np.asarray(exact, np.float32).ravel()
    c_mixed = np.corrcoef(e, np.asarray(mixed, np.float32).ravel())[0, 1]
    c_unif = np.corrcoef(e, np.asarray(uniform, np.float32).ravel())[0, 1]
    assert np.isfinite(np.asarray(mixed, np.float32)).all()
    assert c_mixed > c_unif  # protecting sensitive sites helps
    assert c_mixed < 1.0     # but the middle really is approximate


def test_mixed_policy_decode_matches_forward():
    """Segmented cached forward (cache slicing + concat) must agree with the
    teacher-forced forward under a mixed policy."""
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=64)
    pol = P.ApproxPolicy.first_last_exact(PC3_TR, cfg.n_layers)
    model = build_model(cfg.with_policy(pol))
    params, _ = model.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab)
    full_logits, _ = model.forward(params, {"tokens": toks})

    cache = model.init_cache(1, 8)
    outs = []
    for t in range(6):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_site_paths_stable_across_build_model_reruns():
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    pol = P.ApproxPolicy.uniform(PC3_TR, name="stability-probe")
    batch = {"tokens": jax.random.randint(RNG, (1, 4), 0, cfg.vocab)}

    def traced_sites():
        P.clear_log(pol)
        model = build_model(cfg.with_policy(pol))
        params, _ = model.init(RNG)
        model.forward(params, batch)
        return set(P.resolution_log(pol))

    first = traced_sites()
    second = traced_sites()
    assert first and first == second
    paths = {p for p, _ in first}
    assert "decoder/layer_0/attn/wq" in paths
    assert "decoder/lm_head" in paths


def test_conv_sites_resolve_by_kind():
    cfg = get_config("lenet5")
    pol = P.parse_policy("@conv=exact,*=pc3_tr", name="conv-exact")
    model = build_model(cfg.with_policy(pol))
    params, _ = model.init(RNG)
    P.clear_log(pol)
    images = jnp.zeros((2, 28, 28, 1), jnp.float32)
    logits, _ = model.forward(params, {"images": images})
    assert logits.shape == (2, 10)
    log = P.resolution_log(pol)
    by_path = {p: (k, c) for (p, k), (c, _, _) in log.items()}
    assert by_path["cnn/c1"][0] is P.OpKind.CONV
    assert by_path["cnn/c1"][1].exact
    assert by_path["cnn/f1"][1].variant is Variant.PC3_TR
    assert by_path["cnn/out"][0] is P.OpKind.LM_HEAD


def test_deprecation_shim_builds_uniform_policy():
    cfg = get_config("tinyllama_1_1b").smoke()
    shim = dataclasses.replace(cfg, daism=PC3_TR).approx_policy
    assert shim.rules == ()
    assert shim.default == PC3_TR
    # explicit policy takes precedence over the legacy field
    both = dataclasses.replace(cfg, daism=PC3_TR,
                               policy=P.ApproxPolicy.uniform(FLA))
    assert both.approx_policy.default == FLA


def test_moe_expert_sites_route_through_policy():
    cfg = get_config("qwen3_moe_235b").smoke(n_layers=2, vocab=64)
    pol = P.ApproxPolicy.uniform(PC3_TR, name="moe-probe")
    model = build_model(cfg.with_policy(pol))
    params, _ = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 4), 0, cfg.vocab)}
    P.clear_log(pol)
    logits, _ = model.forward(params, batch)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # the dense reference MoE (no mesh here) must route expert GEMMs
    # through the policy, not silently fall back to exact einsums
    log = P.resolution_log(pol)
    moe = {p: c for (p, k), (c, _, _) in log.items()
           if k is P.OpKind.MOE_EXPERT}
    assert "decoder/layer_0/ffn/w_in" in moe
    assert moe["decoder/layer_0/ffn/w_in"].variant is Variant.PC3_TR

    exact_logits, _ = build_model(cfg).forward(params, batch)
    assert not np.array_equal(np.asarray(logits, np.float32),
                              np.asarray(exact_logits, np.float32))


def test_energy_estimate_orders_policies():
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=64)
    batch = {"tokens": jax.random.randint(RNG, (1, 4), 0, cfg.vocab)}
    pols = [P.ApproxPolicy.uniform(PC3_TR, name="e-uni"),
            P.ApproxPolicy.first_last_exact(PC3_TR, cfg.n_layers,
                                            name="e-mixed")]
    savings = []
    for pol in pols:
        P.clear_log(pol)
        model = build_model(cfg.with_policy(pol))
        params, _ = model.init(RNG)
        model.forward(params, batch)
        used, exact = P.estimated_energy_uj(pol)
        assert 0 < used < exact
        savings.append(1 - used / exact)
        assert "estimated multiply energy" in P.site_report(pol)
    assert savings[0] > savings[1]  # uniform approx saves more than mixed


def test_parse_policy_rejects_duplicate_patterns():
    with pytest.raises(ValueError, match=r"duplicate policy rule .* "
                                         r"rules 0 .* and 1 "):
        P.parse_policy("*/attn/*=exact,*/attn/*=pc3_tr,*=fla")


def test_parse_policy_duplicate_default_key_still_allowed():
    # "default" is a key, not a rule: last assignment wins, no dup error
    pol = P.parse_policy("*/attn/*=exact,default=pc3_tr")
    assert pol.default is not None


# ---------------------------------------------------------------------------
# Pallas interpret auto-selection
# ---------------------------------------------------------------------------

def test_auto_interpret_explicit_setting_wins(monkeypatch):
    # explicit interpret beats the platform, both ways
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert P.auto_interpret(
        dataclasses.replace(PC3_TR, interpret=True)) is True
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert P.auto_interpret(
        dataclasses.replace(PC3_TR, interpret=False)) is False


def test_auto_interpret_none_selects_by_platform(monkeypatch):
    assert PC3_TR.interpret is None
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert P.auto_interpret(PC3_TR) is True
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert P.auto_interpret(PC3_TR) is False


def test_interpret_mode_keys_kernel_cache():
    """interpret is part of DaismConfig, so the jitted-kernel lru_cache
    distinguishes auto (None) from forced modes — no cross-contamination
    when the same variant runs interpreted and compiled in one process."""
    base = DaismConfig(variant=Variant.PC2, backend=Backend.JNP, k_chunk=23)
    forced = dataclasses.replace(base, interpret=True)
    k_auto = P.matmul_kernel(base)
    k_forced = P.matmul_kernel(forced)
    assert k_auto is not k_forced
    assert P.matmul_kernel(dataclasses.replace(base, interpret=True)) \
        is k_forced
    assert P.matmul_kernel(dataclasses.replace(base)) is k_auto


# ---------------------------------------------------------------------------
# Flash-attention dispatch (OpKind.ATTN_QK sites)
# ---------------------------------------------------------------------------

def test_parse_config_flash_token():
    cfg = P.parse_config("pc3_tr:flash")
    assert cfg.variant is Variant.PC3_TR
    assert cfg.backend is Backend.JNP
    assert cfg.attn_kernel == "flash"
    assert P.describe_config(cfg) == "pc3_tr:jnp:flash"
    ex = P.parse_config("exact:flash")
    assert ex.exact and ex.attn_kernel == "flash"
    assert P.describe_config(ex) == "exact:flash"
    with_backend = P.parse_config("fla:pallas:flash")
    assert with_backend.backend is Backend.PALLAS
    assert with_backend.attn_kernel == "flash"
    assert P.parse_config("pc3_tr").attn_kernel == "jnp"
    with pytest.raises(ValueError, match="too many"):
        P.parse_config("fla:jnp:pallas:flash")
    with pytest.raises(ValueError):
        DaismConfig(attn_kernel="bogus")


def test_effective_attn_config_is_opt_in():
    """Catch-all numerics rules must not leak into attention: only the
    ':flash' token changes what an ATTN_QK site runs."""
    assert P.effective_attn_config(PC3_TR) is P.EXACT
    flash = dataclasses.replace(PC3_TR, attn_kernel="flash")
    assert P.effective_attn_config(flash) is flash
    assert P.effective_attn_config(flash, eligible=False) is P.EXACT


def test_attn_site_resolves_effective_config():
    pol = P.parse_policy("*=pc3_tr")  # catch-all, no flash opt-in
    with P.site_scope("decoder"), P.site_scope("layer_0"), \
            P.site_scope("attn"):
        cfg = P.resolve_site(pol, "kernel", P.OpKind.ATTN_QK, jnp.bfloat16,
                             record=False)
    assert cfg is P.EXACT
    flash_pol = P.parse_policy("*/attn/kernel=pc3_tr:flash,*=exact")
    with P.site_scope("decoder"), P.site_scope("layer_0"), \
            P.site_scope("attn"):
        cfg = P.resolve_site(flash_pol, "kernel", P.OpKind.ATTN_QK,
                             jnp.bfloat16, record=False)
        assert cfg.attn_kernel == "flash" and cfg.variant is Variant.PC3_TR
        # ineligible shapes (windowed / per-row / cached decode) fall back
        assert P.resolve_site(flash_pol, "kernel", P.OpKind.ATTN_QK,
                              jnp.bfloat16, record=False,
                              attn_eligible=False) is P.EXACT
        with pytest.raises(ValueError, match="bfloat16-only"):
            P.resolve_site(flash_pol, "kernel", P.OpKind.ATTN_QK,
                           jnp.float32, record=False)


def test_flash_exact_policy_token_identical_to_jnp_path():
    """attend must route through flash_attention_bhsd under a requesting
    policy, and the exact variant must not change a single logit argmax."""
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    model = build_model(cfg.with_policy("*=exact"))
    params, _ = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 64), 0, cfg.vocab)}
    ref, _ = model.forward(params, batch)
    flash_model = build_model(
        cfg.with_policy("*/attn/kernel=exact:flash,*=exact"))
    out, _ = flash_model.forward(params, batch)
    r = np.asarray(ref, np.float32)
    o = np.asarray(out, np.float32)
    np.testing.assert_array_equal(r.argmax(-1), o.argmax(-1))
    np.testing.assert_allclose(o, r, rtol=2e-2, atol=2e-3)


def test_flash_approx_policy_runs_and_records():
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    pol = P.parse_policy("*/attn/kernel=pc3_tr:flash,*=exact", name="fa")
    model = build_model(cfg.with_policy(pol))
    params, _ = model.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 64), 0, cfg.vocab)}
    P.clear_log(pol)
    out, _ = model.forward(params, batch)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    log = P.resolution_log(pol)
    attn_sites = {path: c for (path, kind), (c, _, _) in log.items()
                  if kind is P.OpKind.ATTN_QK}
    assert attn_sites, log.keys()
    assert all(c.attn_kernel == "flash" and c.variant is Variant.PC3_TR
               for c in attn_sites.values())
    # approximate attention must actually change the logits
    ref, _ = build_model(cfg.with_policy("*=exact")).forward(params, batch)
    assert np.abs(np.asarray(out, np.float32)
                  - np.asarray(ref, np.float32)).max() > 1e-3


def test_cached_decode_keeps_exact_fallback():
    """Decode steps use the KV-cache branches, which never pass a policy:
    a flash-requesting policy must not disturb cached decoding (the exact
    flash variant is bit-compatible with the jnp path, so decode-vs-forward
    agreement shows the decode side ignored the flash request)."""
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=64)
    pol = P.parse_policy("*/attn/kernel=exact:flash,*=exact", name="fa2")
    model = build_model(cfg.with_policy(pol))
    params, _ = model.init(RNG)
    toks = jax.random.randint(RNG, (1, 8), 0, cfg.vocab)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(1, 8)
    logits = []
    for t in range(toks.shape[1]):
        step, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        logits.append(step)
    dec = jnp.concatenate(logits, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-3)
