"""Sharding rule engine + MoE dispatch tests (single-device where possible;
mesh-dependent behavior via subprocess in test_system)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import Sharder, base_rules


@pytest.fixture()
def sharder():
    mesh = jax.make_mesh((1,), ("data",))  # single-device 'data' mesh
    rules = base_rules(False)
    return Sharder(mesh, rules)


def test_spec_basic(sharder):
    spec = sharder.spec(("embed", "heads"), (64, 32))
    # 'model' axis absent from this mesh -> dropped; embed->data kept
    assert spec == P("data")


def test_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("model",))
    s = Sharder(mesh, {"kv_heads": "model"})
    # only 1 device: axis size 1 divides everything
    assert s.spec(("kv_heads",), (4,)) == P("model")


def test_divisibility_drops_nondividing_axis():
    import os
    # simulate a 16-wide axis via rule table arithmetic (no devices needed
    # for the pure spec logic: fake axis sizes)
    mesh = jax.make_mesh((1,), ("model",))
    s = Sharder(mesh, {"kv_heads": "model"})
    s._axis_sizes = {"model": 16}
    assert s.spec(("kv_heads",), (4,)) == P()      # 4 % 16 != 0 -> replicate
    assert s.spec(("kv_heads",), (32,)) == P("model")


def test_axis_used_once_per_spec():
    mesh = jax.make_mesh((1,), ("data",))
    s = Sharder(mesh, {"a": "data", "b": "data"})
    s._axis_sizes = {"data": 4}
    spec = s.spec(("a", "b"), (8, 8))
    # the same mesh axis must not shard two dims
    assert spec == P("data")


def test_seq_cache_rule_switch():
    mesh = jax.make_mesh((1,), ("model",))
    base = Sharder(mesh, base_rules(False))
    seqc = Sharder(mesh, base_rules(False, seq_sharded_cache=True))
    base._axis_sizes = {"model": 16}
    seqc._axis_sizes = {"model": 16}
    axes = ("cache_batch", "cache_seq", "act_kv_heads", None)
    assert base.spec(axes, (8, 32768, 4, 64)) == P()
    assert seqc.spec(axes, (8, 32768, 4, 64)) == P(None, "model")


def test_moe_dense_fallback_without_mesh():
    """moe_ffn must run (dense path) with no ambient sharder."""
    from repro.configs import get_config
    from repro.models.moe import moe_ffn
    from repro.models.module import Ctx

    cfg = get_config("dbrx_132b").smoke(n_experts=4, topk=2, d_model=32,
                                        expert_ff=16)
    ctx = Ctx("init", rng=jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 32), jnp.bfloat16)
    out, aux = moe_ffn(ctx, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux) > 0.0  # load-balance loss is positive


def test_moe_capacity_drops_tokens_gracefully():
    from repro.configs import get_config
    from repro.models.moe import _local_dispatch_compute, _route
    from repro.models.module import Ctx
    import dataclasses

    cfg = get_config("dbrx_132b").smoke(n_experts=4, topk=2, d_model=16,
                                        expert_ff=8)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)  # force drops
    rng = jax.random.PRNGKey(0)
    x2d = jax.random.normal(rng, (64, 16), jnp.bfloat16)
    router = jax.random.normal(rng, (16, 4), jnp.float32)
    w_in = jax.random.normal(rng, (4, 16, 8), jnp.bfloat16)
    w_g = jax.random.normal(rng, (4, 16, 8), jnp.bfloat16)
    w_out = jax.random.normal(rng, (4, 8, 16), jnp.bfloat16)
    ids, probs, aux = _route(x2d, router, cfg)
    out = _local_dispatch_compute(x2d, ids, probs, w_in, w_g, w_out, 0, cfg)
    assert out.shape == (64, 16)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    # with drops, some rows are exactly zero (token fully dropped)
    zero_rows = (np.asarray(out, np.float32) == 0).all(axis=1).sum()
    assert zero_rows > 0
