"""Checkpoint atomicity / resume / elastic-restore tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


@pytest.fixture()
def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "step": jnp.int32(7)}}


def test_roundtrip(tmp_path, tree):
    ckpt.save(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    out = ckpt.restore(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_incomplete_checkpoint_ignored(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # simulate a crash mid-write on step 2: delete the sentinel
    os.remove(str(tmp_path / "step_00000002" / ckpt.SENTINEL))
    assert ckpt.latest_step(str(tmp_path)) == 1
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), 2, tree)


def test_missing_key_detected(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree)
    bigger = dict(tree, extra=jnp.zeros((2,)))
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, bigger)


def test_cleanup_keeps_newest(tmp_path, tree):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.cleanup(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    remaining = sorted(os.listdir(tmp_path))
    assert remaining == ["step_00000004", "step_00000005"]


def test_overwrite_same_step(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree)
    tree2 = jax.tree.map(lambda x: x * 0, tree)
    ckpt.save(str(tmp_path), 1, tree2)
    out = ckpt.restore(str(tmp_path), 1, tree)
    assert float(np.asarray(out["a"]).sum()) == 0.0
