"""Approximate GEMM semantics: chunking, autodiff, conv-via-im2col."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Backend, DaismConfig, Variant, conv2d_im2col,
                        daism_dot, daism_matmul)


def _ab(m=16, k=96, n=32, seed=0, dtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(m, k)), dtype),
            jnp.asarray(rng.normal(size=(k, n)), dtype))


def test_k_chunk_invariance():
    a, w = _ab(8, 70, 16)
    base = DaismConfig(variant=Variant.PC3_TR)
    outs = [np.asarray(daism_matmul(a, w, base.replace(k_chunk=c)))
            for c in (7, 32, 70)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_backends_agree():
    a, w = _ab(8, 64, 16, seed=1)
    cfgs = [DaismConfig(variant=Variant.PC3_TR, backend=b)
            for b in (Backend.JNP, Backend.LUT, Backend.PALLAS)]
    outs = [np.asarray(daism_matmul(a, w, c)) for c in cfgs]
    np.testing.assert_array_equal(outs[0], outs[1])  # LUT bit-identical
    np.testing.assert_allclose(outs[2], outs[0], rtol=1e-6, atol=1e-6)


def test_systematic_shrinkage():
    """Approx products are one-sided (|approx| <= |exact|): a GEMM of
    positive operands must come out strictly below the exact result."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(np.abs(rng.normal(size=(8, 128))) + 0.1, jnp.bfloat16)
    w = jnp.asarray(np.abs(rng.normal(size=(128, 8))) + 0.1, jnp.bfloat16)
    exact = np.asarray(a, np.float32) @ np.asarray(w, np.float32)
    for v in (Variant.FLA, Variant.PC3_TR):
        ap = np.asarray(daism_matmul(a, w, DaismConfig(variant=v)))
        assert (ap <= exact + 1e-3).all()
        assert ap.mean() < exact.mean()


def test_ste_gradients_match_exact():
    a, w = _ab(4, 32, 8, seed=3)
    cfg = DaismConfig(variant=Variant.PC3_TR, backward="ste")

    g_approx = jax.grad(lambda w: (daism_matmul(a, w, cfg) ** 2).sum())(w)
    # STE backward uses exact matmul grads of the approx forward output
    out = daism_matmul(a, w, cfg)
    g_manual = jnp.matmul(a.astype(jnp.float32).T, 2 * out)
    # grads are returned in the weight dtype (bf16): compare at bf16 eps
    np.testing.assert_allclose(np.asarray(g_approx, np.float32),
                               np.asarray(g_manual, np.float32),
                               rtol=0.05, atol=0.2)


def test_approx_backward_runs_and_is_finite():
    a, w = _ab(4, 32, 8, seed=4)
    cfg = DaismConfig(variant=Variant.PC3_TR, backward="approx")
    g = jax.grad(lambda w: (daism_matmul(a, w, cfg) ** 2).sum())(w)
    assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


def test_daism_dot_batched_shapes():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 3, 24)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(24, 8)), jnp.bfloat16)
    cfg = DaismConfig(variant=Variant.PC3_TR)
    out = daism_dot(x, w, cfg)
    assert out.shape == (2, 3, 8)
    flat = daism_matmul(x.reshape(-1, 24), w, cfg)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 8),
                               np.asarray(flat), rtol=1e-6)


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_conv_im2col_exact_mode_matches_lax(padding):
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(2, 10, 10, 3)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(3, 3, 3, 5)), jnp.float32)
    exact_cfg = DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT)
    ref = conv2d_im2col(x, k, exact_cfg, padding=padding)
    # approximate path with EXACT variant (exercises im2col + GEMM route)
    cfg = DaismConfig(variant=Variant.EXACT, backend=Backend.JNP)
    got = conv2d_im2col(x, k, cfg, padding=padding)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_conv_approx_close_to_exact():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(3, 3, 3, 4)) * 0.2, jnp.bfloat16)
    ce = np.asarray(conv2d_im2col(
        x, k, DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT)),
        np.float32)
    ca = np.asarray(conv2d_im2col(x, k, DaismConfig(variant=Variant.PC3_TR)))
    rel = np.abs(ce - ca).mean() / np.abs(ce).mean()
    assert rel < 0.1


def test_calibration_reduces_bias():
    """Beyond-paper shrinkage calibration: dividing by E[approx/exact]
    removes the one-sided bias (~4x mean-error cut for FLA)."""
    from repro.core.lut import shrinkage_factor

    rng = np.random.default_rng(12)
    a = jnp.asarray(np.abs(rng.normal(size=(16, 128))) + 0.1, jnp.bfloat16)
    w = jnp.asarray(np.abs(rng.normal(size=(128, 16))) + 0.1, jnp.bfloat16)
    ref = np.asarray(a, np.float32) @ np.asarray(w, np.float32)
    for v in (Variant.FLA, Variant.PC3_TR):
        f = shrinkage_factor(v)
        assert 0.8 < f < 1.0
        e_plain = np.abs(np.asarray(daism_matmul(
            a, w, DaismConfig(variant=v))) - ref).mean()
        e_cal = np.abs(np.asarray(daism_matmul(
            a, w, DaismConfig(variant=v, calibrated=True))) - ref).mean()
        assert e_cal < 0.55 * e_plain, (v, e_plain, e_cal)
