"""Serving-engine tests: scheduler admit/retire, continuous batching,
row reuse isolation, and token-identity of the paged (block-table,
chunked-prefill) engine vs. the single-request decode_step path —
including under mixed per-request approximation policies and prefix-cache
block reuse."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build_model
from repro.runtime.watchdog import StepWatchdog
from repro.serve import (EngineConfig, Request, Scheduler, ServeEngine,
                         poisson_requests, synthetic_requests)

MAX_SEQ = 48


@pytest.fixture(scope="module")
def served():
    cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=128)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


MIXED_SPEC = "*/layer_0/*=exact,@lm_head=exact,*=pc3_tr"


def _reference_generate(model, params, prompt, max_new):
    """The existing single-request path: scalar-pos cache, one decode_step
    per prompt/generated token. The oracle batched serving must match."""
    decode = jax.jit(model.decode_step)
    cache = model.init_cache(1, MAX_SEQ)
    toks = jnp.asarray([prompt], jnp.int32)
    logits = None
    for t in range(len(prompt)):
        logits, cache = decode(params, toks[:, t:t + 1], cache)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    while len(out) < max_new:
        logits, cache = decode(params, jnp.asarray([[tok]], jnp.int32), cache)
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# Scheduler (pure logic, no jax)
# ---------------------------------------------------------------------------

def test_scheduler_admits_and_retires():
    sched = Scheduler(num_slots=2)
    for _ in range(3):
        sched.submit(Request(prompt=[1, 2], max_new_tokens=4))
    admitted = sched.admit(step=0)
    assert [s.slot for s in admitted] == [0, 1]
    assert sched.free_slots == 0 and len(sched.waiting) == 1
    assert sched.admit(step=1) == []  # no free slot -> nobody admitted

    done = sched.retire(0, "length", step=5)
    assert done.finish_reason == "length" and done.slot == -1
    assert sched.free_slots == 1

    late = sched.admit(step=6)
    assert len(late) == 1 and late[0].slot == 0  # freed slot is reused
    assert late[0].joined_running_batch  # slot 1 was still decoding
    assert late[0].request_id == 2
    sched.retire(0, "eos", step=8)
    sched.retire(1, "length", step=8)
    assert not sched.has_work and sched.free_slots == 2


def test_scheduler_arrival_step_gating():
    sched = Scheduler(num_slots=4)
    sched.submit(Request(prompt=[1], max_new_tokens=2, arrival_step=0))
    sched.submit(Request(prompt=[2], max_new_tokens=2, arrival_step=5))
    assert len(sched.admit(step=0)) == 1  # the future arrival must wait
    assert sched.admit(step=4) == []
    assert len(sched.admit(step=5)) == 1


def test_scheduler_unarrived_head_does_not_block():
    """Non-monotonic arrival trace: an unarrived head-of-queue request must
    not starve arrived requests queued behind it."""
    sched = Scheduler(num_slots=2)
    sched.submit(Request(prompt=[1], max_new_tokens=2, arrival_step=10))
    sched.submit(Request(prompt=[2], max_new_tokens=2, arrival_step=0))
    admitted = sched.admit(step=0)
    assert [s.request_id for s in admitted] == [1]
    assert [s.request_id for s in sched.waiting] == [0]  # order preserved
    assert [s.request_id for s in sched.admit(step=10)] == [0]


# ---------------------------------------------------------------------------
# Engine vs. the single-request oracle
# ---------------------------------------------------------------------------

def test_batched_decode_token_identical_to_single_request(served):
    """5 mixed-length requests over 2 slots (forcing slot reuse and
    mid-stream joins) generate exactly the tokens the legacy path does."""
    cfg, model, params = served
    requests = synthetic_requests(5, cfg.vocab, base_prompt=6, base_gen=6,
                                  seed=3)
    expected = {i: _reference_generate(model, params, r.prompt,
                                       r.max_new_tokens)
                for i, r in enumerate(requests)}

    engine = ServeEngine(model, params, EngineConfig(num_slots=2,
                                                     max_seq=MAX_SEQ))
    report = engine.run(requests)
    assert len(report.completed) == 5
    assert report.joined_mid_stream >= 1  # continuous batching exercised
    for state in report.completed:
        assert state.output == expected[state.request_id], state.request_id


def test_slot_reuse_does_not_leak_kv(served):
    """The same prompt served fresh and after slot reuse (with different
    neighbors in the batch) must generate identical tokens — any stale K/V
    from the previous occupant would corrupt the reused slot."""
    cfg, model, params = served
    rng = np.random.default_rng(7)
    twin = rng.integers(0, cfg.vocab, size=7).tolist()
    other = [rng.integers(0, cfg.vocab, size=int(n)).tolist()
             for n in (5, 9, 6)]
    requests = [
        Request(prompt=twin, max_new_tokens=6),      # first wave, slot 0
        Request(prompt=other[0], max_new_tokens=12),  # long-running neighbor
        Request(prompt=other[1], max_new_tokens=4),
        Request(prompt=twin, max_new_tokens=6),      # lands in a reused slot
        Request(prompt=other[2], max_new_tokens=3),
    ]
    engine = ServeEngine(model, params, EngineConfig(num_slots=2,
                                                     max_seq=MAX_SEQ))
    report = engine.run(requests)
    by_id = {s.request_id: s for s in report.completed}
    assert by_id[3].admit_step > 0  # actually reused a slot mid-stream
    assert by_id[0].output == by_id[3].output


def test_eos_retires_early(served):
    cfg, model, params = served
    prompt = [3, 14, 15, 92, 65]
    ref = _reference_generate(model, params, prompt, 8)
    eos = ref[2]
    engine = ServeEngine(model, params, EngineConfig(num_slots=1,
                                                     max_seq=MAX_SEQ))
    report = engine.run([Request(prompt=prompt, max_new_tokens=8,
                                 eos_id=eos)])
    state = report.completed[0]
    assert state.finish_reason == "eos"
    assert state.output == ref[:3]


def test_invalid_requests_rejected(served):
    cfg, model, params = served
    engine = ServeEngine(model, params, EngineConfig(num_slots=1,
                                                     max_seq=16))
    with pytest.raises(ValueError, match="max_seq"):
        engine.submit(Request(prompt=[1] * 10, max_new_tokens=10))
    with pytest.raises(ValueError, match="non-empty"):
        engine.submit(Request(prompt=[], max_new_tokens=4))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(Request(prompt=[1, 2], max_new_tokens=0))


def test_prefill_matches_step_decode_logits(served):
    """Model-level: one batched prefill == stepping the prompt through the
    cache (the old serve path), including right-padded rows."""
    cfg, model, params = served
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 6), 0, cfg.vocab)

    cache = model.init_cache(1, 16)
    step_logits = []
    for t in range(6):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        step_logits.append(np.asarray(lg[:, 0], np.float32))
    ref = np.stack(step_logits, 1)

    # same prompt right-padded to 8 in a 2-row batch: rows are independent
    padded = jnp.zeros((2, 8), jnp.int32).at[0, :6].set(toks[0])
    c2 = model.init_cache(2, 16)
    plg, c2 = model.prefill(params, padded, c2)
    np.testing.assert_allclose(np.asarray(plg[:1, :6], np.float32), ref,
                               rtol=1e-5, atol=1e-5)
    assert int(c2["pos"]) == 8


# ---------------------------------------------------------------------------
# Paged engine: chunked prefill, per-request policies, prefix caching
# ---------------------------------------------------------------------------

def test_chunked_prefill_token_identical_with_small_blocks(served):
    """Prompts longer than prefill_chunk (multi-chunk ingestion) over small
    KV pages (multi-block tables) still generate exactly the tokens of the
    single-request path."""
    cfg, model, params = served
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).tolist()
               for n in (19, 5, 26, 11)]
    requests = [Request(prompt=p, max_new_tokens=4 + i)
                for i, p in enumerate(prompts)]
    expected = [_reference_generate(model, params, r.prompt,
                                    r.max_new_tokens) for r in requests]
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=2, max_seq=MAX_SEQ, block_size=8, prefill_chunk=8))
    report = engine.run(requests)
    assert len(report.completed) == 4
    for state in report.completed:
        assert state.output == expected[state.request_id], state.request_id
    # multi-chunk prefill actually happened: the longest prompt needs 4 ticks
    assert max(s.admit_step for s in report.completed) >= 0
    assert report.kv_util_peak > 0


def test_mixed_policy_tiers_token_identical(served):
    """Per-request policies: base-tier and approximate-tier requests served
    concurrently each match their own single-request oracle, and the engine
    runs one policy group per resolved tier."""
    cfg, model, params = served
    from repro.models.registry import build_model
    approx_model = build_model(cfg.with_policy(MIXED_SPEC))
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).tolist()
               for n in (6, 9, 7)]
    requests = [
        Request(prompt=prompts[0], max_new_tokens=5),               # base
        Request(prompt=prompts[1], max_new_tokens=4, policy="free"),
        Request(prompt=prompts[2], max_new_tokens=4, policy=MIXED_SPEC),
    ]
    expected = {
        0: _reference_generate(model, params, prompts[0], 5),
        1: _reference_generate(approx_model, params, prompts[1], 4),
        2: _reference_generate(approx_model, params, prompts[2], 4),
    }
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=2, max_seq=MAX_SEQ, tiers=(("free", MIXED_SPEC),)))
    report = engine.run(requests)
    assert len(report.completed) == 3
    for state in report.completed:
        assert state.output == expected[state.request_id], state.request_id
    # tier name and equivalent raw spec share one group (one jit'd step)
    assert report.policy_groups == 2


def test_prefix_cache_reuses_blocks_and_stays_identical(served):
    """A later identical prompt adopts the committed prompt blocks
    (cached_len > 0, pool prefix hits) and still generates the exact same
    tokens as the from-scratch path."""
    cfg, model, params = served
    rng = np.random.default_rng(29)
    prompt = rng.integers(0, cfg.vocab, size=21).tolist()
    requests = [
        Request(prompt=prompt, max_new_tokens=4),
        Request(prompt=prompt, max_new_tokens=4, arrival_step=14),
    ]
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=2, max_seq=MAX_SEQ, block_size=8, prefill_chunk=8))
    report = engine.run(requests)
    by_id = {s.request_id: s for s in report.completed}
    assert by_id[1].cached_len >= 8       # at least one full block adopted
    assert report.prefix_hits >= 1
    assert by_id[0].output == by_id[1].output
    assert by_id[0].output == _reference_generate(model, params, prompt, 4)


def test_paged_pool_exceeds_equal_memory_slot_concurrency(served):
    """With pool memory worth 2 max_seq slots, the paged engine runs >2
    short requests concurrently — the concurrency the slot pool capped."""
    cfg, model, params = served
    # pool = 6 blocks of 8 cells = 48 cells = one old max_seq=48 slot * 2...
    # 96 cells == 2 slots of max_seq=48; short requests need 2 blocks each
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=MAX_SEQ, block_size=8, num_blocks=12,
        prefill_chunk=8))
    requests = synthetic_requests(4, cfg.vocab, base_prompt=6, base_gen=6,
                                  seed=5)
    report = engine.run(requests)
    assert len(report.completed) == 4
    assert report.peak_active_requests > 2  # beats the 2-slot equal-memory cap
    for state in report.completed:
        expected = _reference_generate(model, params, state.request.prompt,
                                       state.request.max_new_tokens)
        assert state.output == expected, state.request_id


def test_admission_blocks_on_pool_exhaustion_then_drains(served):
    """A pool too small for two concurrent requests serializes them via
    admission control instead of deadlocking or corrupting K/V."""
    cfg, model, params = served
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=2, max_seq=32, block_size=8, num_blocks=3,
        prefill_chunk=8))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=9).tolist() for _ in range(2)]
    requests = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    report = engine.run(requests)  # each needs 2 blocks; only 3 exist
    assert len(report.completed) == 2
    assert report.peak_active_requests == 1  # second waited for pages
    for state in report.completed:
        expected = _reference_generate(model, params, state.request.prompt, 6)
        assert state.output == expected


def test_engine_config_validation():
    with pytest.raises(ValueError, match="num_slots"):
        EngineConfig(num_slots=0)
    with pytest.raises(ValueError, match="block_size"):
        EngineConfig(block_size=-1)
    with pytest.raises(ValueError, match="multiple"):
        EngineConfig(max_seq=40, block_size=16)
    with pytest.raises(ValueError, match="prefill_chunk.*must be\n?.*<="):
        EngineConfig(max_seq=16, prefill_chunk=32)
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(max_seq=96, prefill_chunk=12)
    with pytest.raises(ValueError, match="tiers"):
        EngineConfig(tiers=(("free", 3),))
    # dict ergonomics + parse_tiers round trip
    from repro.serve import parse_tiers
    tiers = parse_tiers("free=*=pc3_tr;paid=*/attn/*=exact,*=pc3_tr")
    assert tiers == (("free", "*=pc3_tr"),
                     ("paid", "*/attn/*=exact,*=pc3_tr"))
    assert EngineConfig(tiers=dict(tiers)).tiers == tiers
    with pytest.raises(ValueError, match="tier entry"):
        parse_tiers("freepc3_tr")


def test_unknown_tier_rejected(served):
    cfg, model, params = served
    engine = ServeEngine(model, params, EngineConfig(num_slots=1,
                                                     max_seq=16))
    with pytest.raises(ValueError, match="unknown policy tier"):
        engine.submit(Request(prompt=[1, 2], max_new_tokens=2,
                              policy="gold"))


# ---------------------------------------------------------------------------
# Async tick loop, report schema, preemption/swap, sharding config
# ---------------------------------------------------------------------------

def test_scheduler_priority_admission_and_requeue():
    sched = Scheduler(num_slots=1)
    sched.submit(Request(prompt=[1], max_new_tokens=2, priority=0))
    sched.submit(Request(prompt=[2], max_new_tokens=2, priority=5))
    admitted = sched.admit(step=0)
    assert [s.request_id for s in admitted] == [1]  # higher priority wins
    # preemption re-enters at the *front*, ahead of the equal-priority waiter
    sched.submit(Request(prompt=[3], max_new_tokens=2, priority=0))
    state = sched.requeue(admitted[0].slot)
    assert state.preemptions == 1 and state.slot == -1
    assert [s.request_id for s in sched.waiting][0] == 1
    assert [s.request_id for s in sched.admit(step=1)] == [1]


def test_report_schema_latency_percentiles_and_idle(served):
    """Satellite: ServeReport's percentile/async/preemption fields are
    schema-stable — downstream (launch/serve.py, serve_bench.py) reads them
    by name."""
    cfg, model, params = served
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=2, max_seq=32, block_size=8, prefill_chunk=8))
    rng = np.random.default_rng(7)
    requests = [Request(prompt=rng.integers(0, cfg.vocab, size=6).tolist(),
                        max_new_tokens=5) for _ in range(3)]
    report = engine.run(requests)
    for prefix in ("ttft", "latency", "tok_lat"):
        p50, p95, p99 = (getattr(report, f"{prefix}_p{q}_ms")
                         for q in (50, 95, 99))
        assert 0.0 <= p50 <= p95 <= p99
    assert report.ticks > 0
    assert report.host_idle_s >= 0.0
    assert 0.0 <= report.host_idle_frac <= 1.0
    assert report.preemptions == 0 and report.resumes == 0
    assert report.shards == 1
    gaps = sum(len(s.token_gaps_s) for s in report.completed)
    assert gaps == report.generated_tokens - len(report.completed)


def test_sync_tick_loop_token_identical_to_async(served):
    """overlap=False (the synchronous baseline) runs the same schedule —
    admission and batch composition — so tokens must match exactly."""
    cfg, model, params = served
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(5, 12, size=4)]

    def run(overlap):
        engine = ServeEngine(model, params, EngineConfig(
            num_slots=2, max_seq=32, block_size=8, prefill_chunk=8,
            overlap=overlap))
        report = engine.run([
            Request(prompt=p, max_new_tokens=6, arrival_step=i)
            for i, p in enumerate(prompts)])
        return report

    fast, base = run(True), run(False)
    assert ([s.output for s in fast.completed]
            == [s.output for s in base.completed])
    assert fast.host_idle_s >= 0.0 and base.host_idle_s >= 0.0


def test_preempt_then_resume_token_identical(served):
    """Under page exhaustion the preempting engine swaps a victim's pages
    to host and resumes it later; greedy decode must be unaffected."""
    cfg, model, params = served
    # 1-block prompts that grow to 3 blocks each against a 4-page pool:
    # concurrent decode exhausts the pool and forces swaps
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=2, max_seq=32, block_size=8, num_blocks=4,
        prefill_chunk=8, preempt=True))
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, size=6).tolist() for _ in range(3)]
    requests = [Request(prompt=p, max_new_tokens=12) for p in prompts]
    report = engine.run(requests)
    assert report.preemptions >= 1 and report.resumes >= 1
    assert report.resumes == report.preemptions  # everyone came back
    kinds = {ev["event"] for ev in report.events}
    assert {"preempt", "resume"} <= kinds
    assert len(report.completed) == 3
    assert max(s.preemptions for s in report.completed) >= 1
    for state in report.completed:
        expected = _reference_generate(model, params, state.request.prompt,
                                       12)
        assert state.output == expected, f"req {state.request_id} diverged"


def test_preempt_sustains_higher_concurrency_than_reservation(served):
    """Acceptance: optimistic admission + swap serves >= 2x the concurrent
    requests of whole-lifetime reservation from the same pool."""
    cfg, model, params = served
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, size=6).tolist() for _ in range(2)]

    def run(preempt):
        # each request: 1-block prompt, 3-block lifetime; the 3-page pool
        # fits only one whole lifetime but two prompts
        engine = ServeEngine(model, params, EngineConfig(
            num_slots=2, max_seq=32, block_size=8, num_blocks=3,
            prefill_chunk=8, preempt=preempt))
        return engine.run([Request(prompt=p, max_new_tokens=12)
                           for p in prompts])

    reserved, preempting = run(False), run(True)
    assert reserved.peak_active_requests == 1
    assert preempting.peak_active_requests >= 2 * \
        reserved.peak_active_requests
    ref = {tuple(s.request.prompt): s.output for s in reserved.completed}
    for state in preempting.completed:
        assert state.output == ref[tuple(state.request.prompt)]


def test_sharded_engine_requires_matching_mesh(served):
    cfg, model, params = served
    with pytest.raises(ValueError, match="no mesh"):
        ServeEngine(model, params, EngineConfig(num_slots=4, shards=4))


# ---------------------------------------------------------------------------
# Runtime watchdog (shared by train loop + engine)
# ---------------------------------------------------------------------------

def test_watchdog_skips_warmup_and_counts_stragglers():
    dog = StepWatchdog(factor=3.0, alpha=0.5, warmup=1)
    assert not dog.observe(100.0)  # compile step: excluded from the EWMA
    assert not dog.observe(1.0)    # seeds the EWMA
    assert not dog.observe(1.2)
    assert dog.observe(50.0)       # straggler vs ~1.1 EWMA
    assert dog.stragglers == 1
    assert dog.ewma < 30.0


def test_engine_config_rejects_windowed_model(served):
    """Windowed (ring-buffer) caches cannot be paged; the engine rejects
    the combination at construction with the offending field named."""
    cfg, model, params = served
    windowed = dataclasses.replace(cfg, window=16)
    with pytest.raises(ValueError, match=r"ArchConfig\.window=16 .* paged"):
        EngineConfig().validate_for_model(windowed)
    with pytest.raises(ValueError, match=r"ArchConfig\.window"):
        ServeEngine(build_model(windowed), params, EngineConfig(num_slots=1))


# ---------------------------------------------------------------------------
# Self-speculative decoding (draft with the approximate policy, verify exact)
# ---------------------------------------------------------------------------

SPEC_DRAFT = "*=pc3_tr"


def test_spec_config_validation():
    with pytest.raises(ValueError, match="spec_k"):
        EngineConfig(spec_k=2)                    # draft policy missing
    with pytest.raises(ValueError, match="spec_draft"):
        EngineConfig(spec_draft=SPEC_DRAFT)       # k missing
    with pytest.raises(ValueError, match="spec_k"):
        EngineConfig(spec_k=-1, spec_draft=SPEC_DRAFT)
    with pytest.raises(ValueError, match="spec_k"):
        EngineConfig(max_seq=16, block_size=16, spec_k=16,
                     spec_draft=SPEC_DRAFT)       # k >= max_seq
    with pytest.raises(ValueError, match="spec_min_accept"):
        EngineConfig(spec_k=2, spec_draft=SPEC_DRAFT, spec_min_accept=1.5)
    ok = EngineConfig(spec_k=3, spec_draft=SPEC_DRAFT)
    assert ok.spec_k == 3


def test_paged_verify_step_accept_and_bonus_semantics(served):
    """paged_verify_step against the sequential S=1 oracle: correct drafts
    are accepted up to the first mismatch, and the verify logits at the
    last accepted position supply the bonus token."""
    cfg, model, params = served
    block_size, num_blocks = 8, 4
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab, size=6).tolist()
    table = jnp.arange(num_blocks, dtype=jnp.int32)[None, :]

    def fresh_prefill():
        kv = model.init_paged_cache(num_blocks, block_size)
        cache = dict(kv, block_tables=table, pos=jnp.zeros(1, jnp.int32))
        logits, kv = model.paged_step(
            params, jnp.asarray([prompt], jnp.int32), cache,
            block_size=block_size)
        return int(jnp.argmax(logits[0, -1])), kv

    # sequential oracle: t1 from prefill, then three S=1 decode steps
    t1, kv = fresh_prefill()
    toks = [t1]
    for j in range(3):
        cache = dict(kv, block_tables=table,
                     pos=jnp.asarray([len(prompt) + j], jnp.int32))
        logits, kv = model.paged_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            block_size=block_size)
        toks.append(int(jnp.argmax(logits[0, 0])))
    t1, t2, t3, t4 = toks

    def verify(drafts):
        _, kv = fresh_prefill()
        cache = dict(kv, block_tables=table,
                     pos=jnp.asarray([len(prompt)], jnp.int32))
        greedy, n_acc, _ = model.paged_verify_step(
            params, jnp.asarray([[t1] + drafts, ], jnp.int32), cache,
            block_size=block_size)
        return [int(t) for t in greedy[0]], int(n_acc[0])

    wrong = (t4 + 1) % cfg.vocab
    greedy, n_acc = verify([t2, t3, wrong])
    assert greedy[:3] == [t2, t3, t4]  # per-position argmax == sequential
    assert n_acc == 2                  # third draft rejected
    # emitted = accepted drafts + the bonus token from the verify logits
    assert greedy[:n_acc + 1] == [t2, t3, t4]

    _, n_acc = verify([t2, t3, t4])
    assert n_acc == 3                  # perfect drafts: all accepted
    _, n_acc = verify([(t2 + 1) % cfg.vocab, t3, t4])
    assert n_acc == 0                  # first mismatch gates the rest


def test_spec_decode_token_identical_mixed_tiers(served):
    """Acceptance: speculative decode under mixed-tier Poisson traffic is
    token-identical to plain decode, and the draft tier's own group is
    ineligible (it would verify with the numerics it drafted with)."""
    cfg, model, params = served
    tiers = (("free", SPEC_DRAFT), ("paid", MIXED_SPEC))

    def run(spec):
        ecfg = EngineConfig(
            num_slots=4, max_seq=MAX_SEQ, block_size=8, prefill_chunk=8,
            tiers=tiers,
            spec_draft=SPEC_DRAFT if spec else "", spec_k=3 if spec else 0)
        engine = ServeEngine(model, params, ecfg)
        report = engine.run(poisson_requests(
            8, cfg.vocab, rate=0.5, base_prompt=7, base_gen=10, seed=0,
            tiers=["free", "paid"]))
        return engine, report

    _, plain = run(False)
    engine, spec = run(True)
    assert ([s.output for s in spec.completed]
            == [s.output for s in plain.completed])
    assert spec.spec_steps >= 1
    assert 0.0 <= spec.spec_accept_rate <= 1.0
    assert spec.spec_tokens_per_step >= 1.0  # bonus token floor
    # the free tier resolves to the draft policy: that group never drafts
    by_key = {g.label: g.spec_on for g in engine.groups.values()}
    assert by_key["free"] is False
    assert any(s.spec_drafted > 0 for s in spec.completed)
    for s in spec.completed:
        assert 0 <= s.spec_accepted <= s.spec_drafted


def test_spec_decode_with_preemption_rolls_back_and_drains(served):
    """Speculation + preemption: rejected-draft pages are truncated back to
    the pool, preempted rows resume, tokens stay identical to the plain
    reserve engine, and the pool drains to zero pages in use."""
    cfg, model, params = served
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab, size=6).tolist() for _ in range(4)]

    def burst():
        return [Request(prompt=p, max_new_tokens=18) for p in prompts]

    ref = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=32, block_size=8, num_blocks=4,
        prefill_chunk=8)).run(burst())
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=4, max_seq=32, block_size=8, num_blocks=4,
        prefill_chunk=8, preempt=True, spec_draft=SPEC_DRAFT, spec_k=3))
    report = engine.run(burst())
    assert report.preemptions >= 1 and report.resumes == report.preemptions
    assert report.spec_steps >= 1
    assert ([s.output for s in report.completed]
            == [s.output for s in ref.completed])
    stats = engine.pool.stats()
    assert stats["blocks_in_use"] == 0  # no leaked speculative pages


def test_spec_controller_disables_low_acceptance_group(served):
    """The EWMA controller shuts a group's speculation off after the warmup
    once acceptance sinks below spec_min_accept, emitting a spec_off
    event; identity never depended on it (the group just runs S=1)."""
    cfg, model, params = served
    engine = ServeEngine(model, params, EngineConfig(
        num_slots=2, max_seq=MAX_SEQ, spec_draft=SPEC_DRAFT, spec_k=3,
        spec_min_accept=0.9))
    group = engine._group_for(None)
    assert group.spec_on
    for _ in range(engine._SPEC_WARMUP):
        engine._update_spec_controller(group, [0.0, 0.1])
    assert group.spec_on is False
    offs = [ev for ev in engine.events if ev["event"] == "spec_off"]
    assert len(offs) == 1 and offs[0]["group"] == group.label
    # permanent for the run: further observations don't resurrect it
    engine._update_spec_controller(group, [1.0])
    assert group.spec_on is False
