"""Pallas kernel validation: shape/dtype/variant sweep vs the pure-jnp
oracle (bit-exact within one K block; accumulation-order tolerance across
K blocks), including the pad-to-tile path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import Backend, DaismConfig, Variant
from repro.kernels.ops import daism_matmul_pallas
from repro.kernels.ref import daism_matmul_ref

VARIANTS = [Variant.FLA, Variant.HLA, Variant.PC2, Variant.PC3,
            Variant.PC2_TR, Variant.PC3_TR]

SHAPES = [
    (8, 128, 128),     # exactly one tile
    (16, 128, 256),    # multi-tile N
    (24, 256, 128),    # multi-tile K (accumulation loop)
    (5, 70, 33),       # ragged -> pad path
    (1, 1, 1),         # degenerate
]


def _data(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.bfloat16)
    return a, w


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_kernel_matches_oracle(shape, variant):
    m, k, n = shape
    a, w = _data(m, k, n)
    cfg = DaismConfig(variant=variant, backend=Backend.PALLAS)
    got = np.asarray(daism_matmul_pallas(a, w, cfg))
    ref = np.asarray(daism_matmul_ref(a, w, variant))
    # per-element products are bit-identical (tested via the LUT backend in
    # test_gemm); the reduction differs only in f32 summation order
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape", SHAPES)
def test_exact_kernel_matches_matmul(shape):
    m, k, n = shape
    a, w = _data(m, k, n, seed=1)
    cfg = DaismConfig(variant=Variant.EXACT, backend=Backend.PALLAS)
    got = np.asarray(daism_matmul_pallas(a, w, cfg))
    ref = np.asarray(a, np.float32) @ np.asarray(w, np.float32)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)


def test_block_shape_invariance():
    """Different BlockSpec tilings must agree (modulo accumulation order)."""
    a, w = _data(16, 256, 256, seed=2)
    outs = []
    for bm, bk, bn in [(8, 128, 128), (16, 256, 128), (8, 256, 256)]:
        cfg = DaismConfig(variant=Variant.PC3_TR, backend=Backend.PALLAS,
                          block_m=bm, block_k=bk, block_n=bn)
        outs.append(np.asarray(daism_matmul_pallas(a, w, cfg)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-4)


def test_zero_padding_is_semantics_preserving():
    a, w = _data(5, 70, 33, seed=3)
    cfg = DaismConfig(variant=Variant.FLA, backend=Backend.PALLAS)
    got = np.asarray(daism_matmul_pallas(a, w, cfg))
    ref = np.asarray(daism_matmul_ref(a, w, Variant.FLA))
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-5)


def test_f32_inputs_rejected():
    a = jnp.zeros((8, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    cfg = DaismConfig(variant=Variant.PC3_TR, backend=Backend.PALLAS)
    with pytest.raises(ValueError):
        daism_matmul_pallas(a, w, cfg)
