"""Continuous-batching serving demo: per-request policy tiers on one engine.

One paged ServeEngine (8-token KV pages, chunked prefill) serves six
requests: three prompts, each submitted twice — once under the "exact"
tier and once under the "approx" (PC3_TR) tier. The engine batches rows
by resolved policy into one jit'd step per group, so exact and
approximate traffic decode side by side without recompiles; the KV pool
is shared, but prefix caching is policy-keyed, so approximate K/V never
leaks into the exact tier. The paired greedy generations are compared
token by token — the serving analogue of examples/approx_lm_inference.py.

Run:  PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve import EngineConfig, Request, ServeEngine

cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=128, window=0)
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

engine = ServeEngine(model, params, EngineConfig(
    num_slots=2, max_seq=64, block_size=8, prefill_chunk=8,
    tiers=(("exact", "*=exact"), ("approx", "*=pc3_tr"))))

rng = np.random.default_rng(1)
requests = []
for i, (plen, gen) in enumerate(((11, 8), (5, 6), (17, 10))):
    prompt = rng.integers(0, cfg.vocab, size=plen).tolist()
    for tier in ("exact", "approx"):
        requests.append(Request(prompt=prompt, max_new_tokens=gen,
                                arrival_step=2 * i, policy=tier))

report = engine.run(requests)
for ev in report.events:
    what = (f"admit  req {ev['request_id']} -> {ev['group']}/row {ev['slot']}"
            if ev["event"] == "admit"
            else f"retire req {ev['request_id']} "
                 f"({ev['group']}/row {ev['slot']}, {ev['reason']})")
    print(f"step {ev['step']:3d}  {what}")
print(report.summary())

print("\nexact vs pc3_tr greedy generations (same prompt, paired tiers):")
done = sorted(report.completed, key=lambda s: s.request_id)
for e, a in zip(done[0::2], done[1::2]):  # submissions alternate tiers
    n = min(len(e.output), len(a.output))
    agree = sum(x == y for x, y in zip(e.output, a.output)) / max(n, 1)
    print(f"req {e.request_id}/{a.request_id}: token agreement "
          f"{agree * 100:5.1f}%  exact={e.output[:8]}  pc3_tr={a.output[:8]}")
