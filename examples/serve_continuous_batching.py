"""Continuous-batching serving demo: exact vs DAISM-approximate decode.

Six mixed-length requests share two KV slots; as short requests finish,
waiting ones join the running decode batch (watch the admit/retire
timeline). The same workload is then served with the paper's PC3_TR
approximate multiplier and the greedy generations are compared token by
token — the serving analogue of examples/approx_lm_inference.py.

Run:  PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.core import Backend, DaismConfig, Variant
from repro.models.registry import build_model
from repro.serve import EngineConfig, ServeEngine, synthetic_requests

cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=128)
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
engine_cfg = EngineConfig(num_slots=2, max_seq=64)


def serve(model_variant):
    engine = ServeEngine(model_variant, params, engine_cfg)
    report = engine.run(synthetic_requests(6, cfg.vocab, seed=1))
    return report


report = serve(model)
for ev in report.events:
    what = (f"admit  req {ev['request_id']} -> slot {ev['slot']}"
            if ev["event"] == "admit"
            else f"retire req {ev['request_id']} ({ev['reason']})")
    print(f"step {ev['step']:3d}  {what}")
print(report.summary())

approx_cfg = dataclasses.replace(
    cfg, daism=DaismConfig(variant=Variant.PC3_TR, backend=Backend.JNP))
approx_report = serve(build_model(approx_cfg))

print("\nexact vs pc3_tr greedy generations:")
approx_by_id = {s.request_id: s for s in approx_report.completed}
for e in sorted(report.completed, key=lambda s: s.request_id):
    a = approx_by_id[e.request_id]
    n = min(len(e.output), len(a.output))
    agree = sum(x == y for x, y in zip(e.output, a.output)) / max(n, 1)
    print(f"req {e.request_id}: token agreement {agree * 100:5.1f}%  "
          f"exact={e.output[:8]}  pc3_tr={a.output[:8]}")
