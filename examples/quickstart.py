"""Quickstart: the DAISM approximate multiplier in 30 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ALL_VARIANTS, Backend, DaismConfig, Variant,
                        approx_mul, daism_matmul)

# 1. scalar approximate multiplication (paper core concept) -------------
x = jnp.bfloat16(1.375)
w = jnp.bfloat16(-2.5)
print(f"exact        : {float(x) * float(w):+.6f}")
for v in ALL_VARIANTS:
    print(f"{v.value:8s}     : {float(approx_mul(x, w, v)):+.6f}")

# 2. approximate GEMM with exact accumulation ---------------------------
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(8, 64)), jnp.bfloat16)
b = jnp.asarray(rng.normal(size=(64, 8)), jnp.bfloat16)
exact = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
for backend in (Backend.JNP, Backend.LUT, Backend.PALLAS):
    cfg = DaismConfig(variant=Variant.PC3_TR, backend=backend)
    out = np.asarray(daism_matmul(a, b, cfg))
    rel = np.abs(out - exact).mean() / np.abs(exact).mean()
    print(f"GEMM {backend.value:6s}: mean rel err vs exact = {rel:.4f}")

# 3. it differentiates (straight-through backward) ----------------------
cfg = DaismConfig(variant=Variant.PC3_TR)
g = jax.grad(lambda w: (daism_matmul(a, w, cfg) ** 2).sum())(b)
print("grad ok:", g.shape, bool(jnp.isfinite(g.astype(jnp.float32)).all()))
