"""End-to-end driver: train LeNet-5 (~100k params) for a few hundred steps
on synthetic MNIST, then evaluate under every DAISM multiplier — the paper's
Table-2 experiment as a runnable example — plus a *mixed* per-site policy
(first conv + classifier head exact, middle layers PC3_tr) through the
repro.policy API, with its per-site resolution/energy report.

Run:  PYTHONPATH=src python examples/train_lenet_daism.py [--steps 300]
      [--policy 'cnn/c1=exact,@lm_head=exact,*=pc3_tr']
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import policy as P
from repro.configs import get_config
from repro.core import ALL_VARIANTS, Backend, DaismConfig
from repro.data.synthetic import eval_set, image_batches
from repro.models.cnn import CNNModel
from repro.models.registry import classifier_loss
from repro.optim import AdamWConfig, apply_updates, init_state

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=300)
p.add_argument("--policy", default="cnn/c1=exact,@lm_head=exact,*=pc3_tr",
               help="mixed per-site policy evaluated after the variant sweep")
args = p.parse_args()

cfg = get_config("lenet5")
model = CNNModel(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
opt = init_state(params)
ocfg = AdamWConfig(lr=1e-3, weight_decay=0.01)


@jax.jit
def step(params, opt, images, labels):
    def loss_fn(p):
        logits, _ = model.forward(p, {"images": images})
        return classifier_loss(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = apply_updates(params, grads, opt, ocfg)
    return params, opt, loss


gen = image_batches(10, 64, shape=(28, 28, 1), noise=0.5, seed=0)
for i in range(args.steps):
    b = next(gen)
    params, opt, loss = step(params, opt, jnp.asarray(b["images"]),
                             jnp.asarray(b["labels"]))
    if i % 50 == 0:
        print(f"step {i:4d} loss {float(loss):.4f}")

test = eval_set(image_batches(10, 64, shape=(28, 28, 1), noise=0.5,
                              seed=99), 4)


def accuracy(cfg_eval):
    m = CNNModel(cfg_eval)
    correct = total = 0
    for b in test:
        logits, _ = m.forward(params, {"images": jnp.asarray(b["images"])})
        correct += (np.asarray(jnp.argmax(logits, -1)) == b["labels"]).sum()
        total += len(b["labels"])
    return correct / total


print(f"\n{'multiplier':28s} accuracy")
print(f"{'exact':28s} {accuracy(cfg) * 100:6.2f}%")
for v in ALL_VARIANTS:
    pol = P.ApproxPolicy.uniform(DaismConfig(variant=v, backend=Backend.JNP))
    print(f"{v.value:28s} {accuracy(cfg.with_policy(pol)) * 100:6.2f}%")

# mixed per-site policy: sensitive sites exact, middle approximate
mixed = P.parse_policy(args.policy)
print(f"{'mixed(' + args.policy + ')':28s} "
      f"{accuracy(cfg.with_policy(mixed)) * 100:6.2f}%")
print()
print(P.site_report(mixed))
