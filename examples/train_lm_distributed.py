"""Distributed LM training example: 8 fake devices, (4 data x 2 model) mesh,
FSDP+TP sharding, checkpointed + resumable. The same build_artifacts path the
multi-pod dry-run lowers for 512 chips.

Run:  PYTHONPATH=src python examples/train_lm_distributed.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro.configs import get_config
from repro.data.synthetic import lm_batches, shard_batch
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_artifacts
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import TrainLoopConfig, run

mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_config("tinyllama_1_1b").smoke(n_layers=2, vocab=128)
art = build_artifacts(cfg, mesh, opt_cfg=AdamWConfig(lr=3e-3),
                      total_steps=100, warmup=5)
params = art.init_params(jax.random.PRNGKey(0))
opt = art.init_opt(params)
gen = lm_batches(cfg.vocab, 16, 32, seed=0)
bsh = art.batch_sharding(next(gen))

loop = TrainLoopConfig(total_steps=100, ckpt_dir="/tmp/repro_example_ckpt",
                       ckpt_every=25, log_every=10)
params, opt, state = run(
    loop, art.train_step, params, opt, gen,
    lambda b: shard_batch(b, bsh),
    metrics_hook=lambda s, m: print(
        f"step {s:4d} loss {float(m['loss']):.4f}"),
    param_shardings=art.param_shardings, opt_shardings=art.opt_shardings)
print(f"finished at step {state.step} "
      f"(re-run me: I resume from the checkpoint)")
