"""Serve a small LM with DAISM-approximate parameter GEMMs and compare
generations + logit fidelity against the exact model — the paper's technique
applied to a transformer (beyond the paper's CNNs).

Run:  PYTHONPATH=src python examples/approx_lm_inference.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Backend, DaismConfig, Variant
from repro.models.registry import build_model

cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=128)
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)

logits_exact, _ = model.forward(params, {"tokens": prompt})

for v in (Variant.FLA, Variant.PC3, Variant.PC3_TR):
    c = dataclasses.replace(cfg, daism=DaismConfig(variant=v,
                                                   backend=Backend.JNP))
    logits_v, _ = build_model(c).forward(params, {"tokens": prompt})
    e = np.asarray(logits_exact, np.float32).ravel()
    a = np.asarray(logits_v, np.float32).ravel()
    corr = np.corrcoef(e, a)[0, 1]
    agree = (np.asarray(jnp.argmax(logits_exact, -1))
             == np.asarray(jnp.argmax(logits_v, -1))).mean()
    print(f"{v.value:8s} logit corr {corr:.4f}  next-token agreement "
          f"{agree * 100:.1f}%")
