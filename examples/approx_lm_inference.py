"""Serve a small LM with DAISM-approximate parameter GEMMs and compare
generations + logit fidelity against the exact model — the paper's technique
applied to a transformer (beyond the paper's CNNs), now driven through the
per-site policy API (repro.policy): uniform variants first, then a mixed
policy that keeps the sensitive sites (attention, first/last layer, lm_head)
exact while the middle MLPs run approximate.

Run:  PYTHONPATH=src python examples/approx_lm_inference.py [--policy SPEC]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import policy as P
from repro.configs import get_config
from repro.core import Backend, DaismConfig, Variant
from repro.models.registry import build_model

parser = argparse.ArgumentParser()
parser.add_argument("--policy", default="",
                    help="extra policy spec to evaluate, e.g. "
                         "'*/attn/*=exact,*=pc3_tr'")
args = parser.parse_args()

cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=128)
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)

logits_exact, _ = model.forward(params, {"tokens": prompt})


def fidelity(policy):
    logits_v, _ = build_model(cfg.with_policy(policy)).forward(
        params, {"tokens": prompt})
    e = np.asarray(logits_exact, np.float32).ravel()
    a = np.asarray(logits_v, np.float32).ravel()
    corr = np.corrcoef(e, a)[0, 1]
    agree = (np.asarray(jnp.argmax(logits_exact, -1))
             == np.asarray(jnp.argmax(logits_v, -1))).mean()
    return corr, agree


pc3_tr = DaismConfig(variant=Variant.PC3_TR, backend=Backend.JNP)
policies = [P.ApproxPolicy.uniform(
    DaismConfig(variant=v, backend=Backend.JNP))
    for v in (Variant.FLA, Variant.PC3, Variant.PC3_TR)]
policies += [
    P.ApproxPolicy.first_last_exact(pc3_tr, cfg.n_layers),
    P.ApproxPolicy.attention_exact(pc3_tr),
]
if args.policy:
    policies.append(P.parse_policy(args.policy))

print(f"{'policy':26s} logit-corr  next-token agreement")
for pol in policies:
    corr, agree = fidelity(pol)
    print(f"{pol.name:26s} {corr:10.4f}  {agree * 100:6.1f}%")

# per-site resolution + energy estimate for the last mixed policy
print()
print(P.site_report(policies[-1]))
