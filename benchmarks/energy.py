"""Fig 7/8: energy per multiplication breakdown + relative improvement.

Analytical model (core/energy.py) with literature 45nm constants; validates
the paper's four Fig-7 observations and the Fig-8 exponent-handling study.
"""
from __future__ import annotations

import time

from repro.core import ALL_VARIANTS, Variant
from repro.core import energy as E


def run():
    rows = []
    t0 = time.perf_counter()
    base = {dt: E.total(E.eyeriss_energy_per_mult(dt, truncated=True))
            for dt in ("bfloat16", "float32")}
    for dt in ("bfloat16", "float32"):
        rows.append({"name": f"energy_baseline_{dt}", "us_per_call": 0.0,
                     "pj_per_mult": round(base[dt], 3)})
        for v in ALL_VARIANTS:
            for kb, bus in ((32, 512), (8, 256)):
                bd = E.daism_energy_per_mult(v, dt, bank_kb=kb, bus_bits=bus)
                rows.append({
                    "name": f"energy_{v.value}_{dt}_{kb}kB",
                    "us_per_call": 0.0,
                    "pj_per_mult": round(E.total(bd), 3),
                    "decoder_pj": round(bd["sram_decoder"], 4),
                    "wordline_pj": round(bd["sram_wordline"], 4),
                    "vs_baseline_pct": round(
                        (base[dt] - E.total(bd)) / base[dt] * 100, 1),
                })
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        r["us_per_call"] = round(dt_us, 2)

    def total_of(v, dt, kb):
        return next(r["pj_per_mult"] for r in rows
                    if r["name"] == f"energy_{v}_{dt}_{kb}kB")

    claims = {
        # Fig 7 observation 1: decoder cost negligible (<5% of total) for
        # the single-read variants (HLA pays the decoder twice and is
        # rejected by the paper anyway — observation 3)
        "decoder_negligible": all(
            r.get("decoder_pj", 0) / r["pj_per_mult"] < 0.05
            for r in rows if "decoder_pj" in r and "hla" not in r["name"]),
        # observation 3: HLA at least as power-hungry as the baseline
        "hla_not_viable": total_of("hla", "bfloat16", 32) >= base["bfloat16"],
        # observation 4: 32kB vs 8kB per-op energy within 10%
        "bank_size_insensitive": abs(
            total_of("pc3_tr", "bfloat16", 32) - total_of("pc3_tr", "bfloat16", 8)
        ) / total_of("pc3_tr", "bfloat16", 32) < 0.10,
        # truncation nearly halves energy (doubles ops per read)
        "truncation_big_win": total_of("pc3_tr", "bfloat16", 32)
        < 0.6 * total_of("pc3", "bfloat16", 32),
        # PC3 slightly cheaper than PC2 (fewer active wordlines)
        "pc3_cheaper_than_pc2": total_of("pc3_tr", "bfloat16", 32)
        < total_of("pc2_tr", "bfloat16", 32),
        # Fig 8: improvement with exponent handling, bf16 32kB
        "fig8_bf16_improvement_pct": round(E.relative_improvement(
            Variant.PC3_TR, "bfloat16", bank_kb=32, bus_bits=512) * 100, 1),
        "fig8_f32_improvement_pct": round(E.relative_improvement(
            Variant.PC3_TR, "float32", bank_kb=32, bus_bits=512) * 100, 1),
    }
    return rows, claims


if __name__ == "__main__":
    rows, claims = run()
    for r in rows:
        print(r)
    print(claims)
