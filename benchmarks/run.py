"""Benchmark harness: one module per paper table/figure (DESIGN.md section 6).

Prints ``name,us_per_call,derived`` CSV rows plus per-benchmark claim
checks. Each suite's rows + claims are written to ``BENCH_<name>.json`` at
the repo root (``serve_bench`` -> ``BENCH_serve.json``) — small checked-in
artifacts a reviewer can diff without rerunning the suite — and the
combined results go to results/benchmarks.json. The dry-run/roofline
tables (EXPERIMENTS.md Dry-run/Roofline) come from ``repro.launch.dryrun``,
which needs the 512-device environment and is run separately.

``--check`` turns the harness into a regression gate: instead of writing
artifacts it re-runs each suite fresh and compares the claims a suite
names in its module-level ``REGRESSION_CLAIMS`` dict against the
checked-in ``BENCH_<name>.json``. A named claim that moved >20% in the
bad direction ("higher"/"lower" = which way is better), or a boolean
claim that held in the artifact but fails fresh, exits 1. Artifacts whose
recorded platform differs from the current runtime are skipped with a
notice (a CPU CI run cannot invalidate a TPU artifact), so the gate is
safe to wire into CI unconditionally.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_MODULES = ("error_distance", "energy", "arch_cycles", "gemm_bench",
            "attn_bench", "accuracy", "policy_sweep", "serve_bench")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _artifact_path(name: str) -> str:
    short = name[:-len("_bench")] if name.endswith("_bench") else name
    return os.path.join(_REPO_ROOT, f"BENCH_{short}.json")


def _meta() -> dict:
    """Provenance stamp: which code/runtime produced the artifact, so
    cross-PR perf trajectories are comparable (and non-comparable runs —
    different device counts, jax versions — are visibly so)."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=_REPO_ROOT, capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except OSError:
        sha = "unknown"
    import jax

    return {"git_sha": sha, "jax": jax.__version__,
            "devices": jax.device_count(),
            "platform": jax.default_backend()}


# --check regression tolerance: a named numeric claim may move up to this
# fraction in the bad direction before the gate fails (absorbs smoke-run
# noise on shared CI machines; real regressions from e.g. a lost kernel
# fusion or a broken speculative accept path move far more than 20%)
_CHECK_TOLERANCE = 0.20


def _check(only) -> None:
    """Compare fresh claims against checked-in artifacts; exit 1 on a >20%
    regression of any claim named in a suite's ``REGRESSION_CLAIMS``."""
    meta = _meta()
    failures, notices = [], []
    for name in only:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        spec = getattr(mod, "REGRESSION_CLAIMS", None)
        if not spec:
            notices.append(f"{name}: no REGRESSION_CLAIMS declared, skipped")
            continue
        path = _artifact_path(name)
        if not os.path.exists(path):
            notices.append(f"{name}: no checked-in artifact at {path}, "
                           "skipped")
            continue
        with open(path) as f:
            artifact = json.load(f)
        old_platform = artifact.get("meta", {}).get("platform")
        if old_platform != meta["platform"]:
            notices.append(
                f"{name}: artifact platform {old_platform!r} != current "
                f"{meta['platform']!r}, skipped (not comparable)")
            continue
        t0 = time.perf_counter()
        _, fresh = mod.run()
        dt = time.perf_counter() - t0
        print(f"# check {name} ({dt:.1f}s)", flush=True)
        baseline = artifact.get("claims", {})
        for key, direction in spec.items():
            if key not in baseline:
                notices.append(f"{name}.{key}: not in artifact (new claim), "
                               "skipped")
                continue
            if key not in fresh:
                failures.append(f"{name}.{key}: claim vanished from suite")
                continue
            old, new = baseline[key], fresh[key]
            if isinstance(old, bool) or isinstance(new, bool):
                if old is True and new is not True:
                    failures.append(f"{name}.{key}: held in artifact, "
                                    f"now {new}")
                continue
            old, new = float(old), float(new)
            worse = (new < old * (1 - _CHECK_TOLERANCE)
                     if direction == "higher"
                     else new > old * (1 + _CHECK_TOLERANCE))
            status = "REGRESSED" if worse else "ok"
            print(f"check,{name}.{key},{old} -> {new},{status}", flush=True)
            if worse:
                failures.append(
                    f"{name}.{key}: {old} -> {new} "
                    f"({direction} is better, tolerance "
                    f"{_CHECK_TOLERANCE:.0%})")
    for n in notices:
        print(f"# notice: {n}")
    if failures:
        print(f"# {len(failures)} regression(s):")
        for f_ in failures:
            print(f"#   {f_}")
        raise SystemExit(1)
    print("# regression gate: all named claims within tolerance")


def main() -> None:
    argv = sys.argv[1:]
    if "--check" in argv:
        argv.remove("--check")
        _check(argv or _MODULES)
        return
    only = argv or _MODULES
    meta = _meta()
    all_rows, all_claims = [], {}
    for name in only:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        rows, claims = mod.run()
        dt = time.perf_counter() - t0
        print(f"# {name} ({dt:.1f}s)", flush=True)
        for r in rows:
            derived = {k: v for k, v in r.items()
                       if k not in ("name", "us_per_call")}
            print(f"{r['name']},{r['us_per_call']},{json.dumps(derived)}",
                  flush=True)
        for k, v in claims.items():
            print(f"claim,{name}.{k},{v}", flush=True)
        with open(_artifact_path(name), "w") as f:
            json.dump({"suite": name, "elapsed_s": round(dt, 1),
                       "meta": meta, "rows": rows, "claims": claims}, f,
                      indent=1, default=str)
            f.write("\n")
        all_rows += rows
        all_claims.update({f"{name}.{k}": v for k, v in claims.items()})
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump({"meta": meta, "rows": all_rows, "claims": all_claims},
                  f, indent=1, default=str)
    failed = [k for k, v in all_claims.items() if v is False]
    print(f"# claims: {sum(1 for v in all_claims.values() if v is True)} "
          f"hold, {len(failed)} failed: {failed}")


if __name__ == "__main__":
    main()
