"""Benchmark harness: one module per paper table/figure (DESIGN.md section 6).

Prints ``name,us_per_call,derived`` CSV rows plus per-benchmark claim
checks. Each suite's rows + claims are written to ``BENCH_<name>.json`` at
the repo root (``serve_bench`` -> ``BENCH_serve.json``) — small checked-in
artifacts a reviewer can diff without rerunning the suite — and the
combined results go to results/benchmarks.json. The dry-run/roofline
tables (EXPERIMENTS.md Dry-run/Roofline) come from ``repro.launch.dryrun``,
which needs the 512-device environment and is run separately.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_MODULES = ("error_distance", "energy", "arch_cycles", "gemm_bench",
            "attn_bench", "accuracy", "policy_sweep", "serve_bench")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _artifact_path(name: str) -> str:
    short = name[:-len("_bench")] if name.endswith("_bench") else name
    return os.path.join(_REPO_ROOT, f"BENCH_{short}.json")


def _meta() -> dict:
    """Provenance stamp: which code/runtime produced the artifact, so
    cross-PR perf trajectories are comparable (and non-comparable runs —
    different device counts, jax versions — are visibly so)."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=_REPO_ROOT, capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except OSError:
        sha = "unknown"
    import jax

    return {"git_sha": sha, "jax": jax.__version__,
            "devices": jax.device_count(),
            "platform": jax.default_backend()}


def main() -> None:
    only = sys.argv[1:] or _MODULES
    meta = _meta()
    all_rows, all_claims = [], {}
    for name in only:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        rows, claims = mod.run()
        dt = time.perf_counter() - t0
        print(f"# {name} ({dt:.1f}s)", flush=True)
        for r in rows:
            derived = {k: v for k, v in r.items()
                       if k not in ("name", "us_per_call")}
            print(f"{r['name']},{r['us_per_call']},{json.dumps(derived)}",
                  flush=True)
        for k, v in claims.items():
            print(f"claim,{name}.{k},{v}", flush=True)
        with open(_artifact_path(name), "w") as f:
            json.dump({"suite": name, "elapsed_s": round(dt, 1),
                       "meta": meta, "rows": rows, "claims": claims}, f,
                      indent=1, default=str)
            f.write("\n")
        all_rows += rows
        all_claims.update({f"{name}.{k}": v for k, v in claims.items()})
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump({"meta": meta, "rows": all_rows, "claims": all_claims},
                  f, indent=1, default=str)
    failed = [k for k, v in all_claims.items() if v is False]
    print(f"# claims: {sum(1 for v in all_claims.values() if v is True)} "
          f"hold, {len(failed)} failed: {failed}")


if __name__ == "__main__":
    main()
