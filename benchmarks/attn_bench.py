"""Flash-attention micro-bench: fused Pallas kernel vs the jnp online-softmax
production path, exact and DAISM-approximate, across sequence lengths.

Three implementations per sequence length (B=1, H=2 GQA over KH=1, D=64,
causal, bf16):

* ``attend_jnp``   — ``models.layers.attend`` (chunked online-softmax, the
  production path the flash kernel replaces),
* ``flash_exact``  — ``kernels.flash_attention_bhsd`` with MXU contractions,
* ``flash_approx`` — the same kernel with the PC3_TR shift-plane product
  fused into the QK/PV contractions (scores and approximate products stay
  VMEM-resident).

On this CPU container the Pallas rows run in interpret mode, so wall times
measure *relative* overheads only — the data-movement win the kernel exists
for (no materialized score tensors in HBM) shows up on TPU, not here. The
checked-in claim is numerical: flash_exact must match attend to well under
one bf16 ulp of the output scale (token-identity at the model level —
verified end to end in tests/test_flash_attention.py).
"""
from __future__ import annotations

import functools
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Variant
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.models.layers import attend

B, H, KH, D = 1, 2, 1, 64
SEQS = (256, 1024, 4096)
SMOKE_SEQS = (256,)


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = False):
    rows = []
    exact_err = 0.0
    approx_err = 0.0
    rng = np.random.default_rng(0)
    for s in (SMOKE_SEQS if smoke else SEQS):
        q = jnp.asarray(rng.normal(size=(B, s, H, D)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(B, s, KH, D)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(B, s, KH, D)), jnp.bfloat16)
        pos = jnp.arange(s)
        impls = {
            "attend_jnp": jax.jit(functools.partial(
                lambda p, q, k, v: attend(q, k, v, p, p, causal=True),
                pos)),
            "flash_exact": jax.jit(functools.partial(
                flash_attention_bhsd, causal=True)),
            "flash_approx": jax.jit(functools.partial(
                flash_attention_bhsd, causal=True, variant=Variant.PC3_TR)),
        }
        outs = {}
        iters = 1 if s >= 4096 else 3  # interpret mode: keep 4k rows cheap
        for name, fn in impls.items():
            us = _time(fn, q, k, v, iters=iters)
            outs[name] = fn(q, k, v).astype(jnp.float32)
            rows.append({"name": f"attn_s{s}_{name}",
                         "us_per_call": round(us, 1), "seq": s})
        exact_err = max(exact_err, float(jnp.max(jnp.abs(
            outs["flash_exact"] - outs["attend_jnp"]))))
        approx_err = max(approx_err, float(jnp.max(jnp.abs(
            outs["flash_approx"] - outs["flash_exact"]))))
    claims = {
        # token-identity surrogate: one bf16 ulp at the unit output scale
        # is 1/128; the kernels agree far below it (usually bit-identical)
        "flash_exact_vs_attend_max_abs_err": round(exact_err, 6),
        "flash_exact_matches_attend": bool(exact_err <= 1.0 / 128),
        # PC3_TR numerics shift vs exact — informational, must stay small
        "flash_approx_vs_exact_max_abs_err": round(approx_err, 6),
    }
    return rows, claims


if __name__ == "__main__":
    rows, claims = run(smoke="--smoke" in sys.argv[1:])
    for r in rows:
        print(r)
    print(claims)
