"""DAISM GEMM micro-bench: backends (jnp / LUT / Pallas-interpret) across
shapes, CPU wall time + derived TPU-roofline estimates for the kernel.

Wall times on this CPU container measure *relative* backend overheads; the
derived column estimates the TPU v5e VPU-bound time for the DAISM kernel
(8 shift/OR int32 steps per MAC on the VPU at ~4 Top/s int32) vs the exact
MXU matmul (197 TFLOP/s) — quantifying the honest deployment trade-off
documented in DESIGN.md §2.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Backend, DaismConfig, Variant, daism_matmul

VPU_INT32_OPS = 4e12     # ~per chip
MXU_FLOPS = 197e12
# int32 VPU ops per MAC of the fused PC3 shift-plane kernel
# (kernels/approx_product.approx_matmul_tile). Operand decomposition is
# hoisted out of the K sweep (amortized over the opposite tile edge, ~0 per
# MAC), and the K-sum now folds into the plane loop, so the count is:
#   pre-computed 3-bit head line: mul + shift               = 2
#   5 remaining planes x (select + shift + or)              = 15
#   truncation column mask                                  = 1
#   f32 re-composition (normalize shift/select, exponent
#   add + flush/saturate selects, sign/bit assembly)        = 6
DAISM_OPS_PER_MAC = 24
# pre-fusion count, kept for the claim trajectory in README/CHANGES:
# decompose (4) + 8x(select/or/shift) + normalize + compose = 30


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    shapes = [(128, 512, 512), (256, 1024, 512)]
    for (m, k, n) in shapes:
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.bfloat16)
        macs = m * k * n
        tpu_daism_us = macs * DAISM_OPS_PER_MAC / VPU_INT32_OPS * 1e6
        tpu_exact_us = 2 * macs / MXU_FLOPS * 1e6
        for backend in (Backend.EXACT, Backend.JNP, Backend.LUT,
                        Backend.PALLAS):
            variant = Variant.EXACT if backend is Backend.EXACT \
                else Variant.PC3_TR
            cfg = DaismConfig(variant=variant, backend=backend)
            fn = jax.jit(lambda a, w, c=cfg: daism_matmul(a, w, c))
            us = _time(fn, a, w)
            rows.append({
                "name": f"gemm_{m}x{k}x{n}_{backend.value}",
                "us_per_call": round(us, 1),
                "derived_tpu_us": round(
                    tpu_exact_us if backend is Backend.EXACT
                    else tpu_daism_us, 2),
            })
    claims = {
        "daism_tpu_slowdown_vs_mxu": round(
            DAISM_OPS_PER_MAC / VPU_INT32_OPS / (2 / MXU_FLOPS), 1),
    }
    return rows, claims


if __name__ == "__main__":
    rows, claims = run()
    for r in rows:
        print(r)
    print(claims)
