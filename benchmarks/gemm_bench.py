"""DAISM GEMM micro-bench: backends (jnp / LUT / Pallas-interpret) across
shapes, CPU wall time + derived TPU-roofline estimates for the kernel.

Wall times on this CPU container measure *relative* backend overheads; the
derived column estimates the TPU v5e VPU-bound time for the DAISM kernel
(8 shift/OR int32 steps per MAC on the VPU at ~4 Top/s int32) vs the exact
MXU matmul (197 TFLOP/s) — quantifying the honest deployment trade-off
documented in DESIGN.md §2.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Backend, DaismConfig, Variant, daism_matmul

VPU_INT32_OPS = 4e12     # ~per chip
MXU_FLOPS = 197e12
# int32 VPU op-equivalents per MAC, per backend, from each backend's actual
# op mix (previously one shared constant made the derived column identical
# for all three approximate backends — it distinguished nothing):
#
#  * PALLAS — fused shift-plane kernel (kernels/approx_product
#    .approx_matmul_tile). Operand decomposition is hoisted out of the K
#    sweep (amortized over the opposite tile edge, ~0 per MAC) and the
#    K-sum folds into the plane loop:
#      pre-computed 3-bit head line: mul + shift               = 2
#      5 remaining planes x (select + shift + or)              = 15
#      truncation column mask                                  = 1
#      f32 re-composition (normalize shift/select, exponent
#      add + flush/saturate selects, sign/bit assembly)        = 6  -> 24
#  * JNP — unfused elementwise reference: every MAC pays the full chain,
#    decompose (4) + 8x(select/or/shift) + normalize + compose  -> 30
#  * LUT — gather-bound (core/lut.approx_mul_to_f32_lut): the 8-step chain
#    collapses into one 32 KiB VMEM table read, but per-MAC decompose and
#    re-composition remain and the gather itself runs at ~1/4 ALU
#    throughput on the VPU:
#      decompose (4) + index form max/shift/or (3) + gather (~4
#      ALU-op equivalents) + top/man normalize (4) + compose (6) -> 21
OPS_PER_MAC = {
    Backend.PALLAS: 24,
    Backend.JNP: 30,
    Backend.LUT: 21,
}

# claims guarded by ``run.py --check`` (direction = which way is better)
REGRESSION_CLAIMS = {
    "daism_tpu_slowdown_vs_mxu": "lower",
    "derived_tpu_us_distinct_across_backends": "bool",
}
# deployed-kernel count (Pallas fused shift-plane), used for the headline
# slowdown claim; the pre-fusion JNP mix is the 30 above
DAISM_OPS_PER_MAC = OPS_PER_MAC[Backend.PALLAS]


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    shapes = [(128, 512, 512), (256, 1024, 512)]
    for (m, k, n) in shapes:
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.bfloat16)
        macs = m * k * n
        tpu_exact_us = 2 * macs / MXU_FLOPS * 1e6
        for backend in (Backend.EXACT, Backend.JNP, Backend.LUT,
                        Backend.PALLAS):
            variant = Variant.EXACT if backend is Backend.EXACT \
                else Variant.PC3_TR
            cfg = DaismConfig(variant=variant, backend=backend)
            fn = jax.jit(lambda a, w, c=cfg: daism_matmul(a, w, c))
            us = _time(fn, a, w)
            derived = (tpu_exact_us if backend is Backend.EXACT
                       else macs * OPS_PER_MAC[backend]
                       / VPU_INT32_OPS * 1e6)
            rows.append({
                "name": f"gemm_{m}x{k}x{n}_{backend.value}",
                "us_per_call": round(us, 1),
                "derived_tpu_us": round(derived, 2),
            })
    claims = {
        "daism_tpu_slowdown_vs_mxu": round(
            DAISM_OPS_PER_MAC / VPU_INT32_OPS / (2 / MXU_FLOPS), 1),
        # the derived column must actually distinguish the backends it
        # claims to model — the regression this bench once shipped
        "derived_tpu_us_distinct_across_backends": len(
            set(OPS_PER_MAC.values())) == len(OPS_PER_MAC),
    }
    return rows, claims


if __name__ == "__main__":
    rows, claims = run()
    for r in rows:
        print(r)
    print(claims)
