"""Table 2: model accuracy under each multiplier, LeNet-5 + VGG-16.

Protocol mirrors the paper: train the model with exact numerics, then run
inference with each approximate multiplier (float32 and bfloat16) and
report accuracy. Offline-container adaptations (DESIGN.md §2):

  * datasets are the synthetic MNIST/CIFAR-shaped generators from
    ``repro.data.synthetic`` (same cardinality/shapes; absolute accuracies
    differ from the paper — the claim under test is the ORDERING
    baseline >= PC3_tr >= PC3 >= HLA >= PC2 >> FLA and the small-drop
    magnitude for LeNet);
  * VGG-16 keeps the paper's depth/structure (variation D, 2 FC) at 1/4
    width so CPU training fits the bench budget (depth drives the
    approximation sensitivity the paper reports; noted in EXPERIMENTS.md).

Set REPRO_ACCURACY_FULL=1 for full-width VGG-16 and larger eval sets.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ALL_VARIANTS, Backend, DaismConfig, Variant
from repro.data.synthetic import eval_set, image_batches
from repro.models.cnn import CNNModel
from repro.models.registry import classifier_loss
from repro.optim import AdamWConfig, apply_updates, init_state

FULL = os.environ.get("REPRO_ACCURACY_FULL", "0") == "1"

_VARIANTS = (Variant.EXACT,) + ALL_VARIANTS
_DTYPES = ("float32", "bfloat16")


def _train(model: CNNModel, gen, steps: int, lr: float = 1e-3):
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    ocfg = AdamWConfig(lr=lr, weight_decay=0.01, grad_clip=1.0)

    @jax.jit
    def step(params, opt, images, labels):
        def loss_fn(p):
            logits, _ = model.forward(p, {"images": images})
            return classifier_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = apply_updates(params, grads, opt, ocfg)
        return params, opt, loss

    for _ in range(steps):
        b = next(gen)
        params, opt, loss = step(params, opt, jnp.asarray(b["images"]),
                                 jnp.asarray(b["labels"]))
    return params, float(loss)


def _accuracy(model: CNNModel, params, batches) -> float:
    @jax.jit
    def predict(p, images):
        logits, _ = model.forward(p, {"images": images})
        return jnp.argmax(logits, -1)

    correct = total = 0
    for b in batches:
        pred = np.asarray(predict(params, jnp.asarray(b["images"])))
        correct += (pred == b["labels"]).sum()
        total += len(b["labels"])
    return correct / total


def _cast_params(params, dtype):
    def cast(p):
        if p.dtype in (jnp.float32, jnp.bfloat16):
            return p.astype(dtype)
        return p
    return jax.tree.map(cast, params)


def _eval_table(name: str, base_cfg, params, batches) -> List[Dict]:
    rows = []
    for dtype in _DTYPES:
        p = _cast_params(params, dtype)
        for variant in _VARIANTS:
            daism = DaismConfig(
                variant=variant,
                backend=Backend.EXACT if variant is Variant.EXACT
                else Backend.JNP)
            cfg = dataclasses.replace(base_cfg, daism=daism,
                                      param_dtype=dtype, compute_dtype=dtype)
            model = CNNModel(cfg)
            t0 = time.perf_counter()
            acc = _accuracy(model, p, batches)
            dt = (time.perf_counter() - t0) * 1e6 / max(
                sum(len(b["labels"]) for b in batches), 1)
            rows.append({"name": f"accuracy_{name}_{variant.value}_{dtype}",
                         "us_per_call": round(dt, 1),
                         "accuracy": round(float(acc) * 100, 2)})
    return rows


def run():
    rows = []
    # ---- LeNet-5 on MNIST-shaped synthetic ------------------------------
    lenet_cfg = get_config("lenet5")
    model = CNNModel(lenet_cfg)
    steps = 500 if FULL else 300
    gen = image_batches(10, 64, shape=(28, 28, 1), noise=1.0, seed=0)
    params, loss = _train(model, gen, steps)
    test = eval_set(image_batches(10, 64, shape=(28, 28, 1), noise=1.0,
                                  seed=123), 8 if FULL else 4)
    rows += _eval_table("lenet5", lenet_cfg, params, test)

    # ---- VGG-16 (1/4 width unless FULL) on CIFAR-shaped synthetic -------
    vgg_cfg = get_config("vgg16")
    if not FULL:
        from repro.models import cnn as cnn_mod
        # thin the plan: quarter widths, same depth/structure
        thin = tuple(x if x == "P" else max(16, x // 4)
                     for x in cnn_mod._VGG16)
        cnn_mod._VGG16_ORIG = cnn_mod._VGG16
        cnn_mod._VGG16 = thin
    try:
        model = CNNModel(vgg_cfg)
        gen = image_batches(10, 32, shape=(32, 32, 3), noise=0.9, seed=1)
        params, loss = _train(model, gen, 300 if FULL else 200, lr=1e-3)
        test = eval_set(image_batches(10, 32, shape=(32, 32, 3), noise=0.9,
                                      seed=321), 4 if FULL else 2)
        rows += _eval_table("vgg16", vgg_cfg, params, test)
    finally:
        if not FULL:
            cnn_mod._VGG16 = cnn_mod._VGG16_ORIG

    # paper-ordering claims (Table 2)
    acc = {r["name"]: r["accuracy"] for r in rows}

    def a(net, v, dt="float32"):
        return acc[f"accuracy_{net}_{v}_{dt}"]

    claims = {
        "lenet_fla_small_drop": a("lenet5", "exact") - a("lenet5", "fla") < 5.0,
        "lenet_pc3_recovers": a("lenet5", "exact") - a("lenet5", "pc3") < 1.0,
        "vgg_fla_larger_drop": (a("vgg16", "exact") - a("vgg16", "fla"))
        >= (a("lenet5", "exact") - a("lenet5", "fla")),
        "vgg_pc3_recovers": a("vgg16", "pc3") > a("vgg16", "fla"),
        "truncation_cheap": abs(a("vgg16", "pc3") - a("vgg16", "pc3_tr")) < 1.5,
    }
    return rows, claims


if __name__ == "__main__":
    rows, claims = run()
    for r in rows:
        print(r)
    print(claims)
