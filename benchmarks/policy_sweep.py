"""Accuracy-vs-energy sweep over mixed per-site approximation policies.

The paper's energy/accuracy knob (multiplier variant, truncation) is a
*per-multiplier* choice; the policy API (repro.policy) makes it addressable
per op-site. This sweep measures what that buys: on LeNet-5 (trained exact,
evaluated under each policy — the paper's Table-2 protocol) and on a smoke
TinyLlama (logit fidelity vs the exact forward), each policy reports task
quality next to the analytical multiply-energy estimate (core/energy Eq 4-6)
taken from the static analyzer's site table (repro.analyze — the same
numbers daism-lint reports, no runtime resolution log needed) — so mixed
policies (sensitive sites exact, middle layers approximate) land between
all-exact and all-approximate on both axes.

Standalone:  PYTHONPATH=src:. python benchmarks/policy_sweep.py [--smoke]
Harness:     PYTHONPATH=src:. python benchmarks/run.py policy_sweep
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import policy as P
from repro.analyze import trace_site_graph
from repro.configs import get_config
from repro.core import Backend, DaismConfig, Variant
from repro.data.synthetic import eval_set, image_batches
from repro.models.cnn import CNNModel
from repro.models.registry import build_model, classifier_loss
from repro.optim import AdamWConfig, apply_updates, init_state

PC3_TR = DaismConfig(variant=Variant.PC3_TR, backend=Backend.JNP)
FLA = DaismConfig(variant=Variant.FLA, backend=Backend.JNP)


def _lenet_policies(smoke: bool) -> Dict[str, P.ApproxPolicy]:
    pols = {
        "exact": P.ApproxPolicy.uniform(P.EXACT, name="exact"),
        "uniform_pc3_tr": P.ApproxPolicy.uniform(PC3_TR),
        "mixed_ends_exact": P.parse_policy(
            "cnn/c1=exact,@lm_head=exact,*=pc3_tr",
            name="mixed_ends_exact"),
    }
    if not smoke:
        pols["uniform_fla"] = P.ApproxPolicy.uniform(FLA)
        pols["conv_exact_fc_approx"] = P.parse_policy(
            "@conv=exact,*=pc3_tr", name="conv_exact_fc_approx")
    return pols


def _lm_policies(n_layers: int) -> Dict[str, P.ApproxPolicy]:
    return {
        "uniform_pc3_tr": P.ApproxPolicy.uniform(PC3_TR),
        "first_last_exact": P.ApproxPolicy.first_last_exact(PC3_TR, n_layers),
        "attention_exact": P.ApproxPolicy.attention_exact(PC3_TR),
    }


def _train_lenet(cfg, steps: int):
    model = CNNModel(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.01)

    @jax.jit
    def step(params, opt, images, labels):
        def loss_fn(p):
            logits, _ = model.forward(p, {"images": images})
            return classifier_loss(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = apply_updates(params, grads, opt, ocfg)
        return params, opt, loss

    gen = image_batches(10, 64, shape=(28, 28, 1), noise=0.8, seed=0)
    for _ in range(steps):
        b = next(gen)
        params, opt, _ = step(params, opt, jnp.asarray(b["images"]),
                              jnp.asarray(b["labels"]))
    return params


def _accuracy(cfg, params, batches) -> float:
    model = CNNModel(cfg)
    correct = total = 0
    for b in batches:
        logits, _ = model.forward(params, {"images": jnp.asarray(b["images"])})
        correct += (np.asarray(jnp.argmax(logits, -1)) == b["labels"]).sum()
        total += len(b["labels"])
    return correct / total


def _energy_row(cfg, policy: P.ApproxPolicy, *, batch: int, seq: int = 8):
    """Static per-policy energy from the analyzer's abstract site table
    (eval_shape trace, batch-shaped like the measurement runs) — the sweep
    no longer re-derives MAC counts from the runtime resolution log."""
    graph = trace_site_graph(cfg, policy, batch=batch, seq=seq)
    used, exact = graph.energy_uj()
    saving = 100 * (1 - used / exact) if exact else 0.0
    return round(used, 3), round(saving, 1)


def run(smoke: bool = False):
    rows: List[Dict] = []

    # ---- LeNet-5: train exact once, evaluate each policy ----------------
    cfg = get_config("lenet5")
    params = _train_lenet(cfg, steps=60 if smoke else 300)
    test = eval_set(image_batches(10, 64, shape=(28, 28, 1), noise=0.8,
                                  seed=99), 2 if smoke else 4)
    lenet_acc: Dict[str, float] = {}
    eval_batch = len(test[0]["labels"]) if test else 64
    for name, pol in _lenet_policies(smoke).items():
        ecfg = cfg.with_policy(pol)
        t0 = time.perf_counter()
        acc = _accuracy(ecfg, params, test)
        us = (time.perf_counter() - t0) * 1e6 / max(
            sum(len(b["labels"]) for b in test), 1)
        uj, saving = _energy_row(cfg, pol, batch=eval_batch)
        lenet_acc[name] = float(acc)
        rows.append({"name": f"policy_lenet5_{name}",
                     "us_per_call": round(us, 1),
                     "accuracy": round(float(acc) * 100, 2),
                     "energy_uj": uj, "energy_saving_pct": saving})

    # ---- TinyLlama smoke: logit fidelity vs exact -----------------------
    lm_cfg = get_config("tinyllama_1_1b").smoke(n_layers=4, vocab=128)
    model = build_model(lm_cfg)
    lm_params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, lm_cfg.vocab)
    exact_logits, _ = model.forward(lm_params, {"tokens": toks})
    e = np.asarray(exact_logits, np.float32)
    lm_corr: Dict[str, float] = {}
    for name, pol in _lm_policies(lm_cfg.n_layers).items():
        t0 = time.perf_counter()
        logits, _ = build_model(lm_cfg.with_policy(pol)).forward(
            lm_params, {"tokens": toks})
        us = (time.perf_counter() - t0) * 1e6 / toks.size
        a = np.asarray(logits, np.float32)
        corr = float(np.corrcoef(e.ravel(), a.ravel())[0, 1])
        agree = float((e.argmax(-1) == a.argmax(-1)).mean())
        uj, saving = _energy_row(lm_cfg, pol, batch=toks.shape[0],
                                 seq=toks.shape[1])
        lm_corr[name] = corr
        rows.append({"name": f"policy_tinyllama_{name}",
                     "us_per_call": round(us, 1),
                     "logit_corr": round(corr, 4),
                     "next_token_agreement": round(agree * 100, 1),
                     "energy_uj": uj, "energy_saving_pct": saving})

    by = {r["name"]: r for r in rows}
    mixed = by["policy_lenet5_mixed_ends_exact"]
    uni = by["policy_lenet5_uniform_pc3_tr"]
    claims = {
        # mixed policies sit between all-exact and all-approx on energy
        "mixed_saves_energy": mixed["energy_saving_pct"] > 0,
        "uniform_saves_more": (uni["energy_saving_pct"]
                               >= mixed["energy_saving_pct"]),
        # and cost no more accuracy than the uniform approximation
        "mixed_accuracy_ge_uniform": (lenet_acc["mixed_ends_exact"]
                                      >= lenet_acc["uniform_pc3_tr"] - 0.02),
        # protecting first/last layers + lm_head improves logit fidelity
        "first_last_exact_helps": (lm_corr["first_last_exact"]
                                   >= lm_corr["uniform_pc3_tr"]),
        "exact_baseline_sane": lenet_acc["exact"] > 0.3,
    }
    return rows, claims


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep (CI): fewer steps/policies")
    args = ap.parse_args()
    rows, claims = run(smoke=args.smoke)
    for r in rows:
        print(r)
    failed = [k for k, v in claims.items() if v is False]
    print(claims)
    if failed:
        raise SystemExit(f"policy_sweep claims failed: {failed}")
