"""Fig 9 + headline: DAISM accelerator cycles vs on-chip area vs Eyeriss
executing VGG-8 layer 1 (bfloat16, PC3_tr), across bank configurations.
"""
from __future__ import annotations

import time

from repro.core import Variant
from repro.core import arch_model as A


def run():
    layer = A.ConvLayer()  # VGG-8 L1: 224x224x3, 3x3x3x64
    rows = []
    t0 = time.perf_counter()
    ey = A.eyeriss_cycles(layer)
    ey_area = A.eyeriss_area_mm2()
    ey_energy = A.eyeriss_layer_energy_uj(layer)
    rows.append({"name": "arch_eyeriss", "us_per_call": 0.0,
                 "cycles": int(ey["cycles"]), "area_mm2": round(ey_area, 2),
                 "energy_uj": round(ey_energy, 1), "pe": 168})
    for bc in A.FIG9_CONFIGS:
        d = A.daism_cycles(layer, bc, Variant.PC3_TR)
        rows.append({
            "name": f"arch_daism_{bc.num_banks}x{bc.bank_kbytes}kB",
            "us_per_call": 0.0,
            "cycles": int(d["cycles"]),
            "area_mm2": round(A.daism_area_mm2(bc), 2),
            "energy_uj": round(A.daism_layer_energy_uj(layer, bc), 1),
            "pe": int(d["pe_equivalent"]),
            "utilization": d["utilization"],
        })
    dt_us = (time.perf_counter() - t0) * 1e6 / len(rows)
    for r in rows:
        r["us_per_call"] = round(dt_us, 2)

    by = {r["name"]: r for r in rows}
    d16x32 = by["arch_daism_16x32kB"]
    d16x8 = by["arch_daism_16x8kB"]
    d4x128 = by["arch_daism_4x128kB"]
    d1x512 = by["arch_daism_1x512kB"]
    eyr = by["arch_eyeriss"]
    claims = {
        # Fig 9 geometry
        "single_bank_slowest": d1x512["cycles"] > max(
            d4x128["cycles"], d16x32["cycles"], d16x8["cycles"]),
        "16x32_has_512_pe": d16x32["pe"] == 512,
        "16x8_matches_4x128_cycles": d16x8["cycles"] == d4x128["cycles"],
        "16x8_smallest_area": d16x8["area_mm2"] < min(
            d4x128["area_mm2"], d16x32["area_mm2"], d1x512["area_mm2"],
            eyr["area_mm2"]),
        "banked_beats_eyeriss_cycles": d16x32["cycles"] < eyr["cycles"],
        # headline claims (paper: -25% energy, -43% cycles at similar area;
        # our constants give the numbers below — reported, not asserted ==)
        "headline_cycle_reduction_pct_16x8": round(
            (eyr["cycles"] - d16x8["cycles"]) / eyr["cycles"] * 100, 1),
        "headline_cycle_reduction_pct_16x32": round(
            (eyr["cycles"] - d16x32["cycles"]) / eyr["cycles"] * 100, 1),
        "headline_energy_reduction_pct": round(
            (eyr["energy_uj"] - d16x32["energy_uj"]) / eyr["energy_uj"] * 100, 1),
    }
    return rows, claims


if __name__ == "__main__":
    rows, claims = run()
    for r in rows:
        print(r)
    print(claims)
