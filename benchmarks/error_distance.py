"""Fig 5/6: error distance of INT8 approximate multiplication.

Exhaustive sweep over all 256x256 INT8 operand pairs (the paper's fractal
plot data) for FLA/HLA (Fig 5) and PC2/PC3 (Fig 6), plus the float-mantissa
operating region (both MSBs set) the paper argues PC2/PC3 favor.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import Variant, error_distance
from repro.core.multiplier import approx_mul_uint


def run():
    rows = []
    a = jnp.arange(256, dtype=jnp.int32)[:, None]
    b = jnp.arange(256, dtype=jnp.int32)[None, :]
    exact = a * b
    # mantissa operating region (MSB always set — float mode, paper §3.4)
    hi = slice(128, 256)
    for v in (Variant.FLA, Variant.HLA, Variant.PC2, Variant.PC3):
        t0 = time.perf_counter()
        approx = approx_mul_uint(a, b, 8, v)
        ed = np.asarray(error_distance(exact, approx))
        dt = (time.perf_counter() - t0) * 1e6
        approx_f = approx_mul_uint(a, b, 8, v, msb_always_set=True)
        ed_f = np.asarray(error_distance(exact, approx_f))[hi, hi]
        rows.append({
            "name": f"error_distance_{v.value}",
            "us_per_call": round(dt, 1),
            "mean_ed": round(float(ed.mean()), 5),
            "max_ed": round(float(ed.max()), 5),
            "mean_ed_mantissa_region": round(float(ed_f.mean()), 5),
            "max_ed_mantissa_region": round(float(ed_f.max()), 5),
        })
    # paper claims: HLA < FLA error; PC3 < PC2 < FLA in mantissa region
    byname = {r["name"].split("_")[-1]: r for r in rows}
    claims = {
        "hla_better_than_fla": byname["hla"]["mean_ed"] < byname["fla"]["mean_ed"],
        "pc3_best_mantissa": (byname["pc3"]["mean_ed_mantissa_region"]
                              < byname["pc2"]["mean_ed_mantissa_region"]
                              < byname["fla"]["mean_ed_mantissa_region"]),
        "exact_when_no_collisions": float(np.asarray(error_distance(
            jnp.int32(64) * b[0], approx_mul_uint(
                jnp.full((256,), 64, jnp.int32), b[0], 8, Variant.FLA))).max()) == 0.0,
    }
    return rows, claims


if __name__ == "__main__":
    rows, claims = run()
    for r in rows:
        print(r)
    print(claims)
