"""Serving bench: paged KV cache vs slot pool, exact vs mixed policy tiers.

Drives repro.serve.ServeEngine over a seeded Poisson arrival workload
(with every third prompt repeated, so the prefix cache sees shared-prefix
traffic) in three configurations at EQUAL KV memory (128 cells):

* ``slot``  — block_size == max_seq: one page per request, which is
  exactly the old slot pool (2 slots x 64 tokens).
* ``paged`` — 8 x 16-token pages with 4 decode rows: requests only
  reserve the pages they can actually fill, so the same memory admits
  more concurrent requests.
* ``mixed`` — the paged engine serving two per-request policy tiers
  (free = PC3_TR everywhere, paid = exact attention), batched into one
  jit'd step per resolved policy.

Reports decode tokens/sec, p50/p99 TTFT and request latency, KV-pool
utilization, peak concurrency, and prefix-cache hits. The headline claims:
the paged pool completes identical tokens to the slot pool (the block
table is a pure indexing change) while sustaining strictly higher peak
concurrency from the same memory. Wall times on this CPU container measure
*relative* overhead (the jnp bit-op backend is reference semantics, not a
fast kernel); deployment numbers live in gemm_bench.py.

Standalone:  PYTHONPATH=src python benchmarks/serve_bench.py [--arch A ...]
Harness:     PYTHONPATH=src:. python benchmarks/run.py serve_bench
"""
from __future__ import annotations

import argparse

TIERS = (("free", "*=pc3_tr"), ("paid", "*/attn/*=exact,*=pc3_tr"))


def run(arch: str = "tinyllama_1_1b", requests: int = 10, rate: float = 0.5,
        max_seq: int = 64, base_prompt: int = 20, base_gen: int = 8):
    import jax

    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.serve import EngineConfig, ServeEngine, poisson_requests

    cfg = get_config(arch).smoke(window=0)  # paged pools need non-ring caches
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    def workload(tiers=()):
        return poisson_requests(
            requests, cfg.vocab, rate=rate, base_prompt=base_prompt,
            base_gen=base_gen, seed=0, tiers=tiers, repeat_prompt_every=3)

    # equal KV memory everywhere: 2*64 = 8*16 = 128 cells
    configs = (
        ("slot", EngineConfig(num_slots=2, max_seq=max_seq,
                              block_size=max_seq, prefill_chunk=16), ()),
        ("paged", EngineConfig(num_slots=4, max_seq=max_seq, block_size=16,
                               num_blocks=8 * max_seq // 64,
                               prefill_chunk=16), ()),
        ("mixed", EngineConfig(num_slots=4, max_seq=max_seq, block_size=16,
                               num_blocks=8 * max_seq // 64,
                               prefill_chunk=16, tiers=TIERS),
         [name for name, _ in TIERS]),
    )
    rows, reports = [], {}
    for label, ecfg, tier_names in configs:
        engine = ServeEngine(model, params, ecfg)
        report = engine.run(workload(tier_names))
        reports[label] = report
        rows.append({
            "name": f"serve_{arch}_{label}",
            "us_per_call": round(report.step_p50_ms * 1e3, 1),  # decode step
            "tokens_per_s": round(report.tokens_per_s, 1),
            "ttft_p50_ms": round(report.ttft_p50_ms, 1),
            "ttft_p99_ms": round(report.ttft_p99_ms, 1),
            "latency_p99_ms": round(report.latency_p99_ms, 1),
            "kv_util_mean": round(report.kv_util_mean, 3),
            "kv_util_peak": round(report.kv_util_peak, 3),
            "peak_concurrency": report.peak_active_requests,
            "prefix_hits": report.prefix_hits,
            "policy_groups": report.policy_groups,
            "kv_cells": ecfg.blocks * ecfg.block_size,
        })
    slot, paged, mixed = reports["slot"], reports["paged"], reports["mixed"]
    outputs = {label: [r.output for r in reports[label].completed]
               for label in ("slot", "paged")}
    claims = {
        "all_requests_complete": all(
            len(r.completed) == requests for r in reports.values()),
        # block tables are a pure indexing change: same tokens out
        "paged_tokens_identical_to_slot": outputs["slot"] == outputs["paged"],
        # the headline: same 128 KV cells, strictly more requests in flight
        "paged_concurrency_exceeds_equal_memory_slot":
            paged.peak_active_requests > slot.peak_active_requests,
        "slot_peak_concurrency": slot.peak_active_requests,
        "paged_peak_concurrency": paged.peak_active_requests,
        "prefix_cache_hit_on_repeated_prompts": paged.prefix_hits >= 1,
        "mixed_tier_policy_groups": mixed.policy_groups,
        "mixed_tier_serves_two_groups": mixed.policy_groups == 2,
    }
    return rows, claims


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama_1_1b")
    p.add_argument("--requests", type=int, default=10)
    p.add_argument("--rate", type=float, default=0.5)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--prompt-len", type=int, default=20)
    p.add_argument("--gen", type=int, default=8)
    args = p.parse_args()
    rows, claims = run(arch=args.arch, requests=args.requests,
                       rate=args.rate, max_seq=args.max_seq,
                       base_prompt=args.prompt_len, base_gen=args.gen)
    for r in rows:
        print(r)
    print(claims)
