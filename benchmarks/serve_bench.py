"""Serving bench: paged KV vs slot pool, policy tiers, preemption, sharding.

Drives repro.serve.ServeEngine over seeded workloads in several
configurations and backs the repo's serving claims:

* ``slot`` / ``paged`` / ``mixed`` — equal KV memory (128 cells): the paged
  pool completes identical tokens to the slot pool while sustaining
  strictly higher peak concurrency; mixed-tier traffic batches per
  resolved policy.
* ``reserve`` vs ``preempt`` — same undersized pool: optimistic admission
  with preemption/swap admits >= 2x the concurrent requests of
  whole-lifetime reservation, token-identically.
* ``async`` vs ``sync`` — same workload: the async tick loop (overlapping
  host scheduling with the in-flight device step) spends a smaller
  fraction of wall time blocked on device fetches than the synchronous
  baseline (``ServeReport.host_idle_frac``).
* ``spec_pc3_tr`` / ``spec_pc2_tr`` — the mixed-tier engine with
  self-speculative decoding (cheap-draft k=3 + one exact batched verify):
  token-identical to plain, > 1.5 tokens per verify step, accept rate per
  draft tier.
* ``multi_device`` — subprocess children at 1 vs 4 virtual CPU devices,
  equal total KV memory: the 4-way tensor-parallel engine (sharded params,
  KV pages, and decode step) emits identical tokens — also with
  preemption + speculative decoding stacked on top. The children run f32
  compute so the row-parallel psum reorder (~1e-6) stays far below toy
  logit gaps.

Wall times on this CPU container measure *relative* overhead (the jnp
bit-op backend is reference semantics, not a fast kernel); deployment
numbers live in gemm_bench.py.

Standalone:  PYTHONPATH=src python benchmarks/serve_bench.py [--arch A ...]
Harness:     PYTHONPATH=src:. python benchmarks/run.py serve_bench
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

TIERS = (("free", "*=pc3_tr"), ("paid", "*/attn/*=exact,*=pc3_tr"))

_MULTIDEV_TIERS = (("free", "*=pc3_tr"), ("paid", "*=exact"))

# claims guarded by ``run.py --check`` against the checked-in
# BENCH_serve.json (direction = which way is better; "bool" claims must
# keep holding). Numeric wall-clock rows are deliberately NOT gated — on
# shared CI machines they are too noisy; the named claims below are the
# correctness/efficiency properties the serving engine actually promises.
REGRESSION_CLAIMS = {
    "paged_tokens_identical_to_slot": "bool",
    "preempt_tokens_identical_to_reserve": "bool",
    "spec_tokens_identical_to_plain": "bool",
    "spec_tokens_per_verify_step_exceeds_1_5": "bool",
    "spec_pc3_tr_tokens_per_step": "higher",
    "multi_device_tokens_identical": "bool",
    "multi_device_spec_preempt_tokens_identical": "bool",
}


def _report_row(name, report, ecfg):
    return {
        "name": name,
        "us_per_call": round(report.step_p50_ms * 1e3, 1),  # decode step
        "tokens_per_s": round(report.tokens_per_s, 1),
        "ttft_p50_ms": round(report.ttft_p50_ms, 1),
        "ttft_p99_ms": round(report.ttft_p99_ms, 1),
        "latency_p99_ms": round(report.latency_p99_ms, 1),
        "kv_util_mean": round(report.kv_util_mean, 3),
        "kv_util_peak": round(report.kv_util_peak, 3),
        "peak_concurrency": report.peak_active_requests,
        "prefix_hits": report.prefix_hits,
        "policy_groups": report.policy_groups,
        "kv_cells": ecfg.blocks * ecfg.block_size,
        "host_idle_frac": round(report.host_idle_frac, 4),
        "preemptions": report.preemptions,
        "shards": report.shards,
    }


def _multidevice_child(devices: int, spec: bool = False) -> None:
    """Child mode: serve a fixed mixed-tier Poisson workload on
    ``devices`` virtual CPU devices (sharded when > 1) and print the
    outputs + report numbers as JSON on stdout. ``spec`` additionally
    turns on preemption and self-speculative decoding — the full
    composition (shards x preempt x spec) vs the plain reserve child."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}")
    import jax

    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.serve import EngineConfig, ServeEngine, poisson_requests

    cfg = get_config("tinyllama_1_1b").smoke(
        n_layers=2, vocab=128, window=0, kv_heads=4,
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    mesh = (jax.make_mesh((devices,), ("model",)) if devices > 1 else None)
    # equal total KV memory across device counts: 16 x 8-token pages
    ecfg = EngineConfig(num_slots=4, max_seq=48, block_size=8,
                        num_blocks=16, prefill_chunk=8,
                        tiers=_MULTIDEV_TIERS, shards=devices,
                        preempt=spec,
                        spec_draft="*=pc3_tr" if spec else "",
                        spec_k=3 if spec else 0)
    engine = ServeEngine(model, params, ecfg, mesh=mesh)
    report = engine.run(poisson_requests(
        8, cfg.vocab, rate=0.5, base_prompt=7, base_gen=10, seed=0,
        tiers=[name for name, _ in _MULTIDEV_TIERS]))
    suffix = "_spec" if spec else ""
    print(json.dumps({
        "devices": devices,
        "shards": report.shards,
        "spec_steps": report.spec_steps,
        "spec_tokens_per_step": round(report.spec_tokens_per_step, 3),
        "preemptions": report.preemptions,
        "outputs": {s.request_id: s.output for s in report.completed},
        "row": _report_row(f"serve_multidevice_{devices}dev{suffix}",
                           report, ecfg),
    }))


def _run_multidevice() -> "tuple[list, dict]":
    rows, outs = [], {}
    for devices, spec in ((1, False), (4, False), (4, True)):
        env = dict(os.environ)
        argv = [sys.executable, os.path.abspath(__file__),
                "--multidevice-child", str(devices)]
        if spec:
            argv.append("--multidevice-spec")
        proc = subprocess.run(argv, env=env, capture_output=True, text=True,
                              timeout=560)
        if proc.returncode:
            raise RuntimeError(
                f"multi-device child ({devices} devices, spec={spec}) "
                "failed:\n" + proc.stderr[-3000:])
        payload = json.loads(proc.stdout.strip().splitlines()[-1])
        rows.append(payload["row"])
        outs[(devices, spec)] = payload
    claims = {
        "multi_device_ran_4_shards": outs[(4, False)]["shards"] == 4,
        "multi_device_tokens_identical":
            outs[(1, False)]["outputs"] == outs[(4, False)]["outputs"],
        # the full composition: 4-way sharded + preempting + speculative
        # decode still matches the 1-device plain reserve engine
        "multi_device_spec_preempt_tokens_identical":
            outs[(1, False)]["outputs"] == outs[(4, True)]["outputs"],
        "multi_device_spec_verify_steps": outs[(4, True)]["spec_steps"],
        "multi_device_spec_ran": outs[(4, True)]["spec_steps"] >= 1,
    }
    return rows, claims


def run(arch: str = "tinyllama_1_1b", requests: int = 10, rate: float = 0.5,
        max_seq: int = 64, base_prompt: int = 20, base_gen: int = 8):
    import jax

    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.serve import EngineConfig, ServeEngine, poisson_requests

    cfg = get_config(arch).smoke(window=0)  # paged pools need non-ring caches
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    def workload(tiers=()):
        return poisson_requests(
            requests, cfg.vocab, rate=rate, base_prompt=base_prompt,
            base_gen=base_gen, seed=0, tiers=tiers, repeat_prompt_every=3)

    # equal KV memory everywhere: 2*64 = 8*16 = 128 cells
    configs = (
        ("slot", EngineConfig(num_slots=2, max_seq=max_seq,
                              block_size=max_seq, prefill_chunk=16), ()),
        ("paged", EngineConfig(num_slots=4, max_seq=max_seq, block_size=16,
                               num_blocks=8 * max_seq // 64,
                               prefill_chunk=16), ()),
        ("mixed", EngineConfig(num_slots=4, max_seq=max_seq, block_size=16,
                               num_blocks=8 * max_seq // 64,
                               prefill_chunk=16, tiers=TIERS),
         [name for name, _ in TIERS]),
    )
    rows, reports = [], {}
    for label, ecfg, tier_names in configs:
        engine = ServeEngine(model, params, ecfg)
        report = engine.run(workload(tier_names))
        reports[label] = report
        rows.append(_report_row(f"serve_{arch}_{label}", report, ecfg))

    # -- preemption/swap vs whole-lifetime reservation, same tiny pool ----
    import numpy as np

    rng = np.random.default_rng(21)
    from repro.serve import Request

    burst_prompts = [rng.integers(0, cfg.vocab, size=6).tolist()
                     for _ in range(4)]

    def burst():  # 1-page prompts growing to 3 pages, all arriving at once
        return [Request(prompt=p, max_new_tokens=18) for p in burst_prompts]

    for label, preempt in (("reserve", False), ("preempt", True)):
        ecfg = EngineConfig(num_slots=4, max_seq=32, block_size=8,
                            num_blocks=4, prefill_chunk=8, preempt=preempt)
        report = ServeEngine(model, params, ecfg).run(burst())
        reports[label] = report
        rows.append(_report_row(f"serve_{arch}_{label}", report, ecfg))

    # -- async tick loop vs synchronous baseline, same workload -----------
    # a heavier smoke model so the per-step device compute outlasts jax's
    # dispatch overhead: with the tiny default config the step finishes
    # inside the launch call and there is nothing to overlap
    heavy_cfg = get_config(arch).smoke(window=0, d_model=256, n_layers=4,
                                       d_ff=1024, vocab=512)
    heavy_model = build_model(heavy_cfg)
    heavy_params, _ = heavy_model.init(jax.random.PRNGKey(0))
    for label, overlap in (("async", True), ("sync", False)):
        ecfg = EngineConfig(num_slots=4, max_seq=max_seq, block_size=16,
                            num_blocks=2 * max_seq // 8, prefill_chunk=16,
                            tiers=TIERS, overlap=overlap)
        report = ServeEngine(heavy_model, heavy_params, ecfg).run(
            poisson_requests(12, heavy_cfg.vocab, rate=rate,
                             base_prompt=base_prompt, base_gen=base_gen,
                             seed=0, tiers=[name for name, _ in TIERS]))
        reports[label] = report
        rows.append(_report_row(f"serve_{arch}_{label}", report, ecfg))

    # -- self-speculative decoding: cheap draft + exact verify ------------
    # same mixed-tier engine + workload as "mixed" (the plain baseline),
    # with two draft tiers: the policy-matched pc3_tr and the cruder
    # pc2_tr truncation. "free" (= pc3_tr) is its own draft under the
    # first, so only "paid" speculates there; both groups speculate under
    # pc2_tr. Greedy verify keeps every variant token-identical to plain.
    import dataclasses

    spec_labels = []
    for draft_label, draft in (("pc3_tr", "*=pc3_tr"), ("pc2_tr", "*=pc2_tr")):
        label = f"spec_{draft_label}"
        spec_labels.append(label)
        ecfg = dataclasses.replace(configs[2][1], spec_draft=draft, spec_k=3)
        report = ServeEngine(model, params, ecfg).run(
            workload([name for name, _ in TIERS]))
        reports[label] = report
        row = _report_row(f"serve_{arch}_{label}", report, ecfg)
        row.update({
            "spec_verify_steps": report.spec_steps,
            "spec_accept_rate": round(report.spec_accept_rate, 3),
            "spec_tokens_per_step": round(report.spec_tokens_per_step, 3),
            "spec_disabled_groups": report.spec_disabled_groups,
            "decode_steps": report.decode_steps,
        })
        rows.append(row)

    md_rows, md_claims = _run_multidevice()
    rows += md_rows

    slot, paged, mixed = reports["slot"], reports["paged"], reports["mixed"]
    outputs = {label: [r.output for r in reports[label].completed]
               for label in ("slot", "paged", "mixed", "reserve", "preempt",
                             "async", "sync")}
    claims = {
        "all_requests_complete": all(
            len(reports[label].completed) == expect
            for label, expect in (("slot", requests), ("paged", requests),
                                  ("mixed", requests),
                                  ("reserve", len(burst_prompts)),
                                  ("preempt", len(burst_prompts)),
                                  ("async", 12), ("sync", 12))),
        # block tables are a pure indexing change: same tokens out
        "paged_tokens_identical_to_slot": outputs["slot"] == outputs["paged"],
        # the headline: same 128 KV cells, strictly more requests in flight
        "paged_concurrency_exceeds_equal_memory_slot":
            paged.peak_active_requests > slot.peak_active_requests,
        "slot_peak_concurrency": slot.peak_active_requests,
        "paged_peak_concurrency": paged.peak_active_requests,
        "prefix_cache_hit_on_repeated_prompts": paged.prefix_hits >= 1,
        "mixed_tier_policy_groups": mixed.policy_groups,
        "mixed_tier_serves_two_groups": mixed.policy_groups == 2,
        # preemption: same 4-page pool, >= 2x admitted concurrency,
        # token-identical through the swap/resume cycle
        "preemption_occurred": reports["preempt"].preemptions >= 1,
        "preempt_tokens_identical_to_reserve":
            outputs["reserve"] == outputs["preempt"],
        "preempt_2x_admitted_concurrency":
            reports["preempt"].peak_active_requests
            >= 2 * reports["reserve"].peak_active_requests,
        "reserve_peak_concurrency": reports["reserve"].peak_active_requests,
        "preempt_peak_concurrency": reports["preempt"].peak_active_requests,
        # async loop: same tokens, less wall time blocked on the device
        "async_tokens_identical_to_sync":
            outputs["async"] == outputs["sync"],
        "async_idle_frac_below_sync":
            reports["async"].host_idle_frac < reports["sync"].host_idle_frac,
        "async_host_idle_frac": round(reports["async"].host_idle_frac, 4),
        "sync_host_idle_frac": round(reports["sync"].host_idle_frac, 4),
        # speculative decoding: greedy verify makes acceptance a pure
        # correctness check, so identity is claimed against plain mixed
        "spec_tokens_identical_to_plain": all(
            [r.output for r in reports[lbl].completed] == outputs["mixed"]
            for lbl in spec_labels),
        "spec_tokens_per_verify_step_exceeds_1_5":
            reports["spec_pc3_tr"].spec_tokens_per_step > 1.5,
        "spec_pc3_tr_accept_rate":
            round(reports["spec_pc3_tr"].spec_accept_rate, 3),
        "spec_pc2_tr_accept_rate":
            round(reports["spec_pc2_tr"].spec_accept_rate, 3),
        "spec_pc3_tr_tokens_per_step":
            round(reports["spec_pc3_tr"].spec_tokens_per_step, 3),
        "spec_fewer_decode_steps_than_plain":
            reports["spec_pc3_tr"].decode_steps
            < reports["mixed"].decode_steps,
        **md_claims,
    }
    return rows, claims


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama_1_1b")
    p.add_argument("--requests", type=int, default=10)
    p.add_argument("--rate", type=float, default=0.5)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--prompt-len", type=int, default=20)
    p.add_argument("--gen", type=int, default=8)
    p.add_argument("--multidevice-child", type=int, default=0,
                   help=argparse.SUPPRESS)  # internal: subprocess mode
    p.add_argument("--multidevice-spec", action="store_true",
                   help=argparse.SUPPRESS)  # internal: spec+preempt child
    args = p.parse_args()
    if args.multidevice_child:
        _multidevice_child(args.multidevice_child, spec=args.multidevice_spec)
        raise SystemExit(0)
    rows, claims = run(arch=args.arch, requests=args.requests,
                       rate=args.rate, max_seq=args.max_seq,
                       base_prompt=args.prompt_len, base_gen=args.gen)
    for r in rows:
        print(r)
    print(claims)
