"""Serving bench: continuous-batching throughput/latency, exact vs DAISM.

Drives repro.serve.ServeEngine over the same synthetic mixed-length
workload twice — once with exact MXU matmuls (deployment path) and once
with the paper's PC3_TR approximate multiplier on the jnp backend — and
reports decode tokens/sec plus p50/p99 step and TTFT latencies. Wall times
on this CPU container measure *relative* variant overhead (the jnp bit-op
backend is the reference semantics, not a fast kernel); the deployment
trade-off on real hardware is quantified in gemm_bench.py.

Standalone:  PYTHONPATH=src python benchmarks/serve_bench.py [--arch A ...]
Harness:     PYTHONPATH=src:. python benchmarks/run.py serve_bench
"""
from __future__ import annotations

import argparse
import dataclasses


def run(arch: str = "tinyllama_1_1b", requests: int = 6, slots: int = 2,
        max_seq: int = 64, base_prompt: int = 8, base_gen: int = 8):
    import jax

    from repro.configs import get_config
    from repro.core import Backend, DaismConfig, Variant
    from repro.models.registry import build_model
    from repro.serve import EngineConfig, ServeEngine, synthetic_requests

    cfg = get_config(arch).smoke(window=0)  # slot pools need non-ring caches
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    variants = (
        ("exact", cfg),
        ("pc3_tr", dataclasses.replace(
            cfg, daism=DaismConfig(variant=Variant.PC3_TR,
                                   backend=Backend.JNP))),
    )
    rows, reports = [], {}
    for label, vcfg in variants:
        engine = ServeEngine(build_model(vcfg), params, EngineConfig(
            num_slots=slots, max_seq=max_seq))
        report = engine.run(synthetic_requests(
            requests, vcfg.vocab, base_prompt=base_prompt,
            base_gen=base_gen))
        reports[label] = report
        rows.append({
            "name": f"serve_{arch}_{label}",
            "us_per_call": round(report.step_p50_ms * 1e3, 1),  # decode step
            "tokens_per_s": round(report.tokens_per_s, 1),
            "step_p99_ms": round(report.step_p99_ms, 2),
            "ttft_p50_ms": round(report.ttft_p50_ms, 1),
            "latency_p99_ms": round(report.latency_p99_ms, 1),
            "joined_mid_stream": report.joined_mid_stream,
        })
    exact, approx = reports["exact"], reports["pc3_tr"]
    claims = {
        "all_requests_complete": all(
            len(r.completed) == requests for r in reports.values()),
        "continuous_batching_exercised": all(
            r.joined_mid_stream >= 1 for r in reports.values()),
        "pc3_tr_decode_slowdown_x": round(
            exact.tokens_per_s / approx.tokens_per_s, 2)
        if approx.tokens_per_s else None,
    }
    return rows, claims


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama_1_1b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=2)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--gen", type=int, default=8)
    args = p.parse_args()
    rows, claims = run(arch=args.arch, requests=args.requests,
                       slots=args.slots, max_seq=args.max_seq,
                       base_prompt=args.prompt_len, base_gen=args.gen)
    for r in rows:
        print(r)
    print(claims)
