"""Production serving subsystem: continuous batching over a paged
(block-table) KV cache with per-request approximation-policy tiers — see
the DESIGN notes in engine.py and the allocator in kv_pool.py."""
from .engine import EngineConfig, ServeEngine, ServeReport, parse_tiers
from .kv_pool import BlockPool, blocks_needed
from .scheduler import Request, RequestState, Scheduler
from .workload import poisson_requests, synthetic_requests

__all__ = ["BlockPool", "EngineConfig", "Request", "RequestState",
           "Scheduler", "ServeEngine", "ServeReport", "blocks_needed",
           "parse_tiers", "poisson_requests", "synthetic_requests"]
