"""Production serving subsystem: continuous batching over a slot-based
KV-cache pool (see DESIGN notes in engine.py)."""
from .engine import EngineConfig, ServeEngine, ServeReport
from .scheduler import Request, RequestState, Scheduler
from .workload import synthetic_requests

__all__ = ["EngineConfig", "Request", "RequestState", "Scheduler",
           "ServeEngine", "ServeReport", "synthetic_requests"]
