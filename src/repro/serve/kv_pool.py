"""Paged KV-cache block allocator (vLLM-style block tables).

The engine's physical KV cache is one flat pool of ``num_blocks`` fixed-size
pages (``block_size`` token positions each); a *sequence* owns an ordered
list of block ids — its block table — covering its logical positions
``[0, len)``. This module is the pure-Python bookkeeping side (no jax):

* **Free-list allocation** — ``allocate`` reserves enough blocks for a
  request's whole lifetime (prompt + generation) up front, so a running
  request can never deadlock on pool memory mid-decode; ``extend`` grows a
  table on demand for drivers that prefer lazy growth; ``free`` returns
  blocks at retirement. Double-free and unknown ids raise.
* **Ref-counted blocks + prefix caching** — full *prompt* blocks are
  content-addressed by a chained key over ``(policy_key, token prefix)``.
  A new request whose prompt (under the same numerics policy!) shares a
  committed prefix adopts those blocks (refcount++) and skips recomputing
  them — ``allocate`` returns ``cached_len`` so the engine starts chunked
  prefill at the first uncached token. Blocks enter the cache only after
  the owner's prefill completes (``commit_prefix``), so a reader can never
  adopt K/V that has not been written yet. K/V depend on the approximation
  policy, hence ``policy_key`` participates in the cache key: a ``free``-tier
  and a ``paid``-tier request never share pages.
* **Eviction** — a cached block whose refcount drops to zero stays in the
  prefix cache but becomes *evictable* (LRU): a later identical prompt can
  still hit it, and the allocator reclaims evictable blocks (oldest first)
  only after the plain free list is exhausted.
* **Fragmentation accounting** — ``stats`` / ``utilization`` report live
  tokens vs. reserved cells vs. pool capacity, the numbers serve_bench.py
  uses to demonstrate the paged pool's memory win over the slot pool
  (a slot pool is the degenerate ``block_size == max_seq`` configuration).

* **Speculative rollback** — speculative decoding writes ``k`` draft
  positions ahead of a sequence's committed length and may keep only a
  prefix of them; ``truncate`` rolls the reservation back, freeing pages
  that cover *only* rejected positions while leaving partially-kept pages
  (their stale cells are overwritten in place by the next decode window).

Writes never need copy-on-write: only *full, committed prompt* blocks are
shared, and no request ever writes at a logical position inside its
(committed) prompt prefix again — decode and speculative drafts append
strictly after it (``truncate`` also refuses to cut into prompt pages).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

SENTINEL = -1  # block-table entry for "no page mapped" (jit side drops it)


def blocks_needed(n_tokens: int, block_size: int) -> int:
    """Pages covering ``n_tokens`` logical positions."""
    return max(0, -(-n_tokens // block_size))


@dataclasses.dataclass
class _Sequence:
    blocks: List[int]
    prompt: Tuple[int, ...]
    policy_key: Hashable
    total_len: int          # reserved logical capacity (tokens)
    live_len: int           # tokens actually written so far (fragmentation)
    cached_len: int         # prefix adopted from the cache at allocation
    committed: bool = False


class BlockPool:
    """Allocator + prefix cache over ``num_blocks`` pages of ``block_size``."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError(f"BlockPool.num_blocks must be >= 1 "
                             f"(got {num_blocks})")
        if block_size < 1:
            raise ValueError(f"BlockPool.block_size must be >= 1 "
                             f"(got {block_size})")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks))[::-1]  # pop() -> 0,1..
        self._ref: Dict[int, int] = {}
        # content-addressed prompt blocks: key -> block id, and the reverse
        self._prefix: Dict[Hashable, int] = {}
        self._block_key: Dict[int, Hashable] = {}
        # cached blocks with refcount 0, LRU order (oldest first)
        self._evictable: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._seqs: Dict[Hashable, _Sequence] = {}
        self.prefix_queries = 0
        self.prefix_hits = 0      # blocks adopted from the cache
        self.peak_blocks_in_use = 0

    def __contains__(self, seq_id: Hashable) -> bool:
        return seq_id in self._seqs

    # -- capacity ----------------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free) - len(self._evictable)

    @property
    def blocks_available(self) -> int:
        """Blocks an ``allocate`` call may claim (free + evictable)."""
        return len(self._free) + len(self._evictable)

    def _prefix_key(self, policy_key: Hashable, prompt: Sequence[int],
                    i: int) -> Hashable:
        # chained by construction: the key of block i embeds the whole
        # token prefix up to its end, so equal keys => equal K/V content
        # under the same policy
        return (policy_key, i, tuple(prompt[:(i + 1) * self.block_size]))

    def _lookup(self, prompt: Sequence[int], policy_key: Hashable
                ) -> List[int]:
        """Longest run of committed cached blocks for this prompt. Never
        covers the full prompt: at least one token is left to prefill so
        the engine can compute first-token logits."""
        hits: List[int] = []
        full = (len(prompt) - 1) // self.block_size  # last token excluded
        for i in range(full):
            bid = self._prefix.get(self._prefix_key(policy_key, prompt, i))
            if bid is None:
                break
            hits.append(bid)
        return hits

    def can_allocate(self, prompt: Sequence[int], total_len: int,
                     policy_key: Hashable = None) -> bool:
        hits = self._lookup(prompt, policy_key)
        evict_hits = sum(1 for b in hits if b in self._evictable)
        need_new = blocks_needed(total_len, self.block_size) - len(hits)
        return need_new <= self.blocks_available - evict_hits

    # -- alloc / extend / free --------------------------------------------

    def _claim_block(self) -> int:
        if self._free:
            bid = self._free.pop()
        else:  # reclaim the least-recently-freed cached block
            bid, _ = self._evictable.popitem(last=False)
            key = self._block_key.pop(bid)
            del self._prefix[key]
        self._ref[bid] = 1
        return bid

    def allocate(self, seq_id: Hashable, prompt: Sequence[int],
                 total_len: int, policy_key: Hashable = None
                 ) -> Optional[Tuple[List[int], int]]:
        """Reserve pages for a sequence of ``total_len`` logical positions.

        Returns ``(block_table, cached_len)`` — ``cached_len`` leading
        prompt tokens are covered by adopted prefix-cache blocks and need no
        recompute — or ``None`` when the pool cannot satisfy the request
        (admission control backpressure; no partial state is changed).
        """
        if seq_id in self._seqs:
            raise ValueError(f"sequence {seq_id!r} already allocated")
        if total_len < 1:
            raise ValueError(f"total_len must be >= 1 (got {total_len})")
        if not self.can_allocate(prompt, total_len, policy_key):
            return None
        self.prefix_queries += 1
        hits = self._lookup(prompt, policy_key)
        self.prefix_hits += len(hits)
        for bid in hits:  # adopt: refcount++, pull out of the evictable LRU
            if bid in self._evictable:
                del self._evictable[bid]
                self._ref[bid] = 1
            else:
                self._ref[bid] += 1
        n = blocks_needed(total_len, self.block_size)
        table = hits + [self._claim_block() for _ in range(n - len(hits))]
        cached_len = len(hits) * self.block_size
        self._seqs[seq_id] = _Sequence(
            blocks=table, prompt=tuple(prompt), policy_key=policy_key,
            total_len=total_len, live_len=cached_len, cached_len=cached_len)
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return list(table), cached_len

    def extend(self, seq_id: Hashable, new_total_len: int
               ) -> Optional[List[int]]:
        """Grow a sequence's reservation to ``new_total_len`` positions.
        Returns the new block table, or ``None`` if the pool is exhausted
        (caller decides: wait, preempt, or reject)."""
        seq = self._seqs[seq_id]
        extra = blocks_needed(new_total_len, self.block_size) - len(seq.blocks)
        if extra <= 0:
            seq.total_len = max(seq.total_len, new_total_len)
            return list(seq.blocks)
        if extra > self.blocks_available:
            return None
        seq.blocks.extend(self._claim_block() for _ in range(extra))
        seq.total_len = new_total_len
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return list(seq.blocks)

    def advance(self, seq_id: Hashable, live_len: int) -> None:
        """Record that ``live_len`` logical positions now hold real K/V
        (utilization accounting only; no allocation happens here)."""
        self._seqs[seq_id].live_len = live_len

    def truncate(self, seq_id: Hashable, keep_len: int) -> int:
        """Logically truncate a sequence to ``keep_len`` positions, freeing
        trailing pages past the kept region (speculative-decode rollback).

        ``extend``-ed pages that ended up covering only *rejected* draft
        positions return to the pool immediately; a partially-kept trailing
        page stays (its rejected cells are overwritten in place by the next
        decode/verify window — attention never reads past the row's write
        position, so stale K/V there is inert). Pages covering the prompt
        are never cut: shared committed prefix blocks keep their refcounts
        and later speculation can never invalidate a prefix-cache hit.
        Returns the number of pages released."""
        seq = self._seqs[seq_id]
        if keep_len < 0:
            raise ValueError(f"keep_len must be >= 0 (got {keep_len})")
        keep_blocks = max(blocks_needed(keep_len, self.block_size),
                          blocks_needed(len(seq.prompt), self.block_size))
        freed = 0
        while len(seq.blocks) > keep_blocks:
            bid = seq.blocks.pop()
            self._ref[bid] -= 1
            if self._ref[bid] == 0:
                del self._ref[bid]
                if bid in self._block_key:
                    self._evictable[bid] = None
                else:
                    self._free.append(bid)
            freed += 1
        cover = len(seq.blocks) * self.block_size
        seq.total_len = max(min(seq.total_len, cover), 1)
        seq.live_len = min(seq.live_len, max(keep_len, seq.cached_len))
        return freed

    def commit_prefix(self, seq_id: Hashable) -> int:
        """Publish the sequence's full prompt blocks into the prefix cache
        (call once prefill has written them). Returns #blocks published."""
        seq = self._seqs[seq_id]
        if seq.committed:
            return 0
        seq.committed = True
        published = 0
        full = (len(seq.prompt) - 1) // self.block_size
        for i in range(full):
            bid = seq.blocks[i]
            key = self._prefix_key(seq.policy_key, seq.prompt, i)
            if key in self._prefix or bid in self._block_key:
                continue  # already cached (an adopted block, or a dup)
            self._prefix[key] = bid
            self._block_key[bid] = key
            published += 1
        return published

    def free(self, seq_id: Hashable) -> None:
        """Release the sequence's pages. Cached blocks whose refcount hits
        zero become evictable (still prefix-hittable); uncached ones return
        to the free list."""
        seq = self._seqs.pop(seq_id, None)
        if seq is None:
            raise KeyError(f"sequence {seq_id!r} is not allocated "
                           "(double free?)")
        for bid in seq.blocks:
            self._ref[bid] -= 1
            if self._ref[bid] > 0:
                continue
            del self._ref[bid]
            if bid in self._block_key:
                self._evictable[bid] = None  # newest at the end (LRU front pops)
            else:
                self._free.append(bid)

    # -- accounting --------------------------------------------------------

    def live_tokens(self) -> int:
        return sum(s.live_len for s in self._seqs.values())

    def utilization(self) -> Dict[str, float]:
        """KV memory utilization: live tokens vs reserved cells vs pool.

        ``internal_frag`` is the fraction of *reserved* cells not (yet)
        holding live tokens — the waste a slot pool maximizes and paging
        minimizes."""
        cells = self.num_blocks * self.block_size
        reserved = self.blocks_in_use * self.block_size
        live = self.live_tokens()
        return {
            "pool_util": live / cells if cells else 0.0,
            "reserved_util": reserved / cells if cells else 0.0,
            "internal_frag": (reserved - live) / reserved if reserved else 0.0,
        }

    def stats(self) -> Dict[str, float]:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "blocks_in_use": self.blocks_in_use,
            "blocks_free": len(self._free),
            "blocks_evictable": len(self._evictable),
            "peak_blocks_in_use": self.peak_blocks_in_use,
            "prefix_queries": self.prefix_queries,
            "prefix_hits": self.prefix_hits,
            **self.utilization(),
        }
