"""Continuous-batching request scheduler: priority + FCFS over decode rows.

Iteration-level scheduling (Orca / vLLM style) without async machinery:
the engine runs one batched step at a time; between steps the scheduler
retires finished sequences and admits waiting requests into freed rows, so
new work joins the running batch mid-stream instead of waiting for a full
batch drain. A "slot" is one *decode row* of a policy group's fixed-shape
step — admission binds a request to a row; its KV memory lives elsewhere,
in the paged block pool (kv_pool.py), so admission is additionally gated by
an optional ``can_admit`` callback (page reservation). The engine runs one
Scheduler per resolved approximation policy: requests batch with their tier
and never force a cross-tier recompile.

Admission is priority-then-FCFS: the highest ``Request.priority`` among
arrived waiters wins each free row (ties resolve in queue order, so equal
priorities reproduce the original FCFS behavior exactly). A preempted
request re-enters the queue at the *front* (``requeue``), so it resumes
before equal-priority newcomers.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Callable, Deque, Dict, List, Optional, Union


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival_step`` lets drivers replay a trace:
    the scheduler will not admit the request before that engine step.

    ``policy`` selects the request's approximation numerics tier: ``None``
    (the engine's base model policy), a tier name registered in
    ``EngineConfig.tiers`` (e.g. ``"free"``), a raw policy spec string
    (``"*/attn/*=exact,*=pc3_tr"``), or an ``ApproxPolicy``. Requests with
    the same *resolved* policy share jit'd steps (one policy group each).

    ``priority`` orders admission (higher wins; equal = FCFS) and shields a
    request from preemption: under page exhaustion the engine swaps out the
    lowest-priority running request first."""

    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_step: int = 0
    policy: Union[None, str, "object"] = None  # name | spec | ApproxPolicy
    priority: int = 0


@dataclasses.dataclass
class RequestState:
    """Scheduler-owned runtime state + accounting for one request."""

    request: Request
    request_id: int = -1  # engine-assigned; the Request is never mutated
    slot: int = -1        # decode row within the policy group
    group: str = ""       # resolved policy-group label (accounting)
    output: List[int] = dataclasses.field(default_factory=list)
    eos_id: Optional[int] = None  # resolved (request or engine default)
    finish_reason: str = ""
    admit_step: int = -1
    finish_step: int = -1
    joined_running_batch: bool = False  # admitted while others were decoding
    # chunked-prefill progress: prompt tokens [0, next_pos) are already in
    # the KV pool (cached_len of them adopted from the prefix cache, the
    # rest written by previous chunks); prefill is done when
    # next_pos == len(prompt) and the first token has been emitted.
    next_pos: int = 0
    cached_len: int = 0
    # wall-clock accounting (seconds, engine-stamped). arrival_time is when
    # the request became admissible — equal to submit_time for immediate
    # arrivals, stamped later for arrival_step-gated trace replays, so
    # TTFT/latency never include simulated pre-arrival queueing.
    submit_time: float = 0.0
    arrival_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    prefill_s: float = 0.0  # wall time of the prefill chunks it rode in
    last_token_time: float = 0.0   # stamp of the latest emitted token
    token_gaps_s: List[float] = dataclasses.field(default_factory=list)
    # preemption/swap bookkeeping (engine-owned): ``swap`` holds the
    # host-side K/V snapshot + table length while the request is evicted
    preemptions: int = 0
    swap: Optional[dict] = None
    # speculative-decoding accounting (engine-owned): draft tokens proposed
    # for this request and how many of them the verify step accepted —
    # per-request acceptance feeds the engine's dynamic-k controller
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def ttft_s(self) -> float:
        return self.first_token_time - self.arrival_time

    @property
    def latency_s(self) -> float:
        return self.finish_time - self.arrival_time

    @property
    def seq_len(self) -> int:
        """Logical positions holding real K/V (prefilled + generated)."""
        return self.next_pos + max(0, len(self.output) - 1)

    @property
    def prefilling(self) -> bool:
        return self.slot >= 0 and not self.output


class Scheduler:
    """FCFS continuous-batching scheduler over ``num_slots`` decode rows."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.waiting: Deque[RequestState] = collections.deque()
        self.active: Dict[int, RequestState] = {}   # slot -> state
        self.finished: List[RequestState] = []
        # LIFO pool: a just-retired slot is handed out before older free
        # ones (fresh slots 0..n-1 start in ascending pop order)
        self._free: List[int] = list(range(num_slots))[::-1]
        self._ids = itertools.count()

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def submit(self, request: Request, now: float = 0.0) -> RequestState:
        state = RequestState(request=request, request_id=next(self._ids),
                             eos_id=request.eos_id, submit_time=now,
                             arrival_time=now if request.arrival_step <= 0
                             else 0.0)
        self.waiting.append(state)
        return state

    def admit(self, step: int,
              can_admit: Optional[Callable[[RequestState], bool]] = None
              ) -> List[RequestState]:
        """Bind waiting requests (whose arrival time has come) to free
        rows — highest priority first, FCFS among equals; an unarrived
        request does not block arrived ones queued behind it. ``can_admit``
        gates each admission on external resources (KV page reservation):
        when the chosen candidate is refused, admission stops — strict
        blocking, so a large or high-priority request is not starved by
        smaller ones slipping past it. Returns the newly admitted states;
        the caller must start their prefill before the next decode step."""
        admitted: List[RequestState] = []
        running = bool(self.active)
        while self._free:
            best = -1
            for i, st in enumerate(self.waiting):
                if st.request.arrival_step > step:
                    continue
                if (best < 0 or st.request.priority
                        > self.waiting[best].request.priority):
                    best = i  # strict '>' keeps FCFS order among equals
            if best < 0:
                break
            state = self.waiting[best]
            if can_admit is not None and not can_admit(state):
                break  # blocked on memory: nothing lower slips past
            del self.waiting[best]
            state.slot = self._free.pop()
            state.admit_step = step
            state.joined_running_batch = state.joined_running_batch or running
            self.active[state.slot] = state
            admitted.append(state)
        return admitted

    def requeue(self, slot: int) -> RequestState:
        """Preempt the request in ``slot``: unbind its row and put it back
        at the *front* of the waiting queue (it resumes before any
        equal-priority newcomer). The caller owns KV swap-out/-in."""
        state = self.active.pop(slot)
        state.slot = -1
        state.preemptions += 1
        self._free.append(slot)
        self.waiting.appendleft(state)
        return state

    def retire(self, slot: int, reason: str, step: int,
               now: float = 0.0) -> RequestState:
        """Finish the request in ``slot`` and return the row to the pool."""
        state = self.active.pop(slot)
        state.finish_reason = reason
        state.finish_step = step
        state.finish_time = now
        state.slot = -1
        self._free.append(slot)
        self.finished.append(state)
        return state
