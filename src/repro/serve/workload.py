"""Synthetic serving workloads.

Deterministic mixed-length request sets shared by the serve CLI, the
benchmark, and the example. Two arrival processes:

* :func:`synthetic_requests` — fixed ``arrival_every`` stagger (or all at
  once): the original trace-replay shape, convenient for token-identity
  tests because retirements never all land on the same step.
* :func:`poisson_requests` — a seeded Poisson arrival process (exponential
  inter-arrival gaps in *engine steps*, ``rate`` expected arrivals per
  step): the ROADMAP's serving-benchmark workload, what TTFT/latency
  percentiles should be quoted under.

Both accept ``tiers``: a sequence of policy selectors (tier names, specs,
``ApproxPolicy``, or None) sampled per request with the same seeded rng, so
mixed free/paid traffic is reproducible.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .scheduler import Request

# length stagger patterns (cycled): relative offsets around the base
_PROMPT_STAGGER = (0, 3, -2, 5, 1, -3, 4, 2)
_GEN_STAGGER = (0, -3, 2, 5, -2, 3, -1, 4)


def _lengths(i: int, base_prompt: int, base_gen: int):
    plen = max(2, base_prompt + _PROMPT_STAGGER[i % len(_PROMPT_STAGGER)])
    gen = max(2, base_gen + _GEN_STAGGER[i % len(_GEN_STAGGER)])
    return plen, gen


def _pick_tier(rng: np.random.Generator, tiers: Sequence):
    if not tiers:
        return None
    return tiers[int(rng.integers(0, len(tiers)))]


def synthetic_requests(n: int, vocab: int, *, base_prompt: int = 8,
                       base_gen: int = 8, seed: int = 0,
                       arrival_every: int = 0,
                       tiers: Sequence = ()) -> List[Request]:
    """``n`` requests with staggered lengths. ``arrival_every`` > 0 spaces
    arrivals that many engine steps apart (trace replay); 0 = all at once."""
    rng = np.random.default_rng(seed)
    requests = []
    for i in range(n):
        plen, gen = _lengths(i, base_prompt, base_gen)
        requests.append(Request(
            prompt=rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=gen,
            arrival_step=i * arrival_every,
            policy=_pick_tier(rng, tiers)))
    return requests


def poisson_requests(n: int, vocab: int, *, rate: float = 0.5,
                     base_prompt: int = 8, base_gen: int = 8, seed: int = 0,
                     tiers: Sequence = (),
                     repeat_prompt_every: int = 0) -> List[Request]:
    """``n`` requests arriving by a seeded Poisson process.

    ``rate`` is the expected number of arrivals per engine step; arrival
    steps are the floored cumulative sum of exponential(1/rate) gaps, so
    bursts and lulls both occur (what p99 TTFT is for). Lengths follow the
    same stagger patterns as :func:`synthetic_requests`; token ids come
    from the seeded rng. ``repeat_prompt_every`` > 0 makes every k-th
    request reuse the previous prompt verbatim — a shared-prefix workload
    that exercises the engine's prefix cache."""
    if rate <= 0:
        raise ValueError(f"poisson rate must be > 0 (got {rate})")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / rate, size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    requests: List[Request] = []
    prev_prompt: Optional[List[int]] = None
    for i in range(n):
        plen, gen = _lengths(i, base_prompt, base_gen)
        if (repeat_prompt_every and prev_prompt is not None
                and i % repeat_prompt_every == 0):
            prompt = list(prev_prompt)
        else:
            prompt = rng.integers(0, vocab, size=plen).tolist()
        prev_prompt = prompt
        requests.append(Request(
            prompt=prompt, max_new_tokens=gen,
            arrival_step=int(arrivals[i]),
            policy=_pick_tier(rng, tiers)))
    return requests
