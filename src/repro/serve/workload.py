"""Synthetic serving workloads.

Deterministic mixed-length request sets: prompt/generation lengths follow a
fixed stagger pattern (so retirements never all land on the same step and
continuous batching is actually exercised), token ids come from a seeded
rng. Shared by the serve CLI, the benchmark, and the example.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .scheduler import Request

# length stagger patterns (cycled): relative offsets around the base
_PROMPT_STAGGER = (0, 3, -2, 5, 1, -3, 4, 2)
_GEN_STAGGER = (0, -3, 2, 5, -2, 3, -1, 4)


def synthetic_requests(n: int, vocab: int, *, base_prompt: int = 8,
                       base_gen: int = 8, seed: int = 0,
                       arrival_every: int = 0) -> List[Request]:
    """``n`` requests with staggered lengths. ``arrival_every`` > 0 spaces
    arrivals that many engine steps apart (trace replay); 0 = all at once."""
    rng = np.random.default_rng(seed)
    requests = []
    for i in range(n):
        plen = max(2, base_prompt + _PROMPT_STAGGER[i % len(_PROMPT_STAGGER)])
        gen = max(2, base_gen + _GEN_STAGGER[i % len(_GEN_STAGGER)])
        requests.append(Request(
            prompt=rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=gen,
            arrival_step=i * arrival_every))
    return requests
