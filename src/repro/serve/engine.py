"""Synchronous continuous-batching serving engine over a slot KV pool.

Design (the scaffolding every later scaling PR builds on):

* **Slot pool** — one fixed-capacity cache allocation for the whole engine:
  ``k/v: (layers, num_slots, max_seq, kv_heads, head_dim)`` plus a per-slot
  length vector ``pos: (num_slots,)``. Row ``i`` is an independent request
  at its own offset; the model's per-slot decode path (``cache['pos']`` as
  a vector) masks and writes each row at its own position.
* **Prefill / decode separation** — one jit'd batched prefill ingests whole
  prompts (padded to a shape bucket, so compiles are O(log^2) in practice)
  and yields the first generated token; one jit'd decode step is reused for
  every subsequent token across all slots. Prompt K/V is adopted into the
  pool by a jit'd scatter ("insert") that reads/writes cache rows by slot
  index; out-of-range slot ids (padding rows of the prefill bucket) are
  dropped by the scatter.
* **Donated buffers** — decode and insert donate the pool, so XLA updates
  the cache in place instead of allocating a second pool per token (skipped
  on CPU, where jax does not implement donation and would warn).
* **Continuous batching** — between decode steps the scheduler retires
  finished rows and admits waiting requests into the freed slots
  (scheduler.py); decode always runs the full fixed-shape batch, so no
  recompiles happen at admission/retirement boundaries.
* **Accounting** — per-request TTFT / latency and engine-level
  tokens/sec + step-latency percentiles (ServeReport), with the runtime
  straggler watchdog counting anomalously slow decode steps.

Greedy (argmax) sampling: deterministic, so batched decode is
token-identical to the single-request ``decode_step`` path — asserted in
tests/test_serve.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import round_up as _round_up
from repro.runtime.watchdog import StepWatchdog

from .scheduler import Request, RequestState, Scheduler


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 4        # decode batch width == cache pool rows
    max_seq: int = 128        # per-slot KV capacity (prompt + generation)
    prefill_bucket: int = 16  # prompt lengths padded up to a multiple
    eos_id: Optional[int] = None  # default EOS for requests without one


@dataclasses.dataclass
class ServeReport:
    """Aggregate accounting for one engine run.

    Percentiles are unfiltered wall times: on a cold engine the first
    prefill/decode steps are jit-compile-dominated, so small-workload p99
    (and early TTFT) measure compilation — warm the engine or discount the
    first steps when comparing kernels. The straggler counter already
    excludes warmup (StepWatchdog)."""

    completed: List[RequestState]
    wall_s: float
    prefill_s: float
    decode_s: float
    decode_steps: int
    generated_tokens: int
    tokens_per_s: float
    ttft_p50_ms: float
    ttft_p99_ms: float
    latency_p50_ms: float
    latency_p99_ms: float
    step_p50_ms: float
    step_p99_ms: float
    joined_mid_stream: int
    straggler_steps: int
    events: List[Dict[str, Any]]

    def summary(self) -> str:
        lines = [
            f"requests {len(self.completed)}  generated "
            f"{self.generated_tokens} tok  wall {self.wall_s:.2f}s  "
            f"({self.tokens_per_s:.1f} tok/s decode)",
            f"prefill {self.prefill_s * 1e3:.1f} ms total;  decode step "
            f"p50 {self.step_p50_ms:.2f} / p99 {self.step_p99_ms:.2f} ms"
            f" over {self.decode_steps} steps"
            f" ({self.straggler_steps} stragglers)",
            f"TTFT p50 {self.ttft_p50_ms:.1f} / p99 {self.ttft_p99_ms:.1f} "
            f"ms;  request latency p50 {self.latency_p50_ms:.1f} / p99 "
            f"{self.latency_p99_ms:.1f} ms",
            f"{self.joined_mid_stream} request(s) joined the running batch "
            f"mid-stream (continuous batching)",
        ]
        return "\n".join(lines)


class ServeEngine:
    """Drives a DecoderLM-style model (init_cache / prefill / decode_step)
    through continuous-batching generation. Synchronous: ``run`` blocks
    until every submitted request completes."""

    def __init__(self, model, params, cfg: EngineConfig):
        if not hasattr(model, "prefill"):
            raise TypeError(
                f"{type(model).__name__} has no prefill(); the serving "
                "engine requires the DecoderLM cached-forward API")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.scheduler = Scheduler(cfg.num_slots)

        self.cache = model.init_cache(cfg.num_slots, cfg.max_seq)
        if "abs_pos" in self.cache:
            raise ValueError(
                "slot pool needs a non-ring cache: model window "
                f"{model.cfg.window} < max_seq {cfg.max_seq}")
        # scalar -> per-slot lengths: row i of the pool is at offset pos[i]
        self.cache["pos"] = jnp.zeros((cfg.num_slots,), jnp.int32)
        self._last_tok = np.zeros((cfg.num_slots,), np.int32)

        # donation: in-place pool updates (not implemented on CPU — jax
        # would warn and copy anyway)
        donate = jax.default_backend() != "cpu"

        def prefill_fn(params, tokens, lens):
            # scratch cache sized to the prompt bucket, not max_seq: prefill
            # attention and allocation scale with the prompt, and the slack
            # rows of the pool slot keep their previous occupant's K/V —
            # never attended, by the same write-before-visible invariant
            # that covers prompt padding (see DecoderLM.prefill)
            pcache = model.init_cache(tokens.shape[0], tokens.shape[1])
            logits, pcache = model.prefill(params, tokens, pcache)
            last = jnp.take_along_axis(logits, (lens - 1)[:, None, None],
                                       axis=1)  # (R, 1, V) at true length
            return jnp.argmax(last[:, 0, :], -1), pcache["k"], pcache["v"]

        def insert_fn(cache, k, v, slots, lens):
            # adopt prefill K/V into pool rows by slot index; padding rows
            # carry slot id == num_slots (out of range) and are dropped.
            # k/v: (L, R, spad, KH, HD) — jax scatter keeps the advanced
            # index axis in place, so no transpose is needed.
            spad = k.shape[2]
            return dict(
                cache,
                k=cache["k"].at[:, slots, :spad].set(k, mode="drop"),
                v=cache["v"].at[:, slots, :spad].set(v, mode="drop"),
                pos=cache["pos"].at[slots].set(lens, mode="drop"))

        def decode_fn(params, cache, tokens):
            logits, cache = model.decode_step(params, tokens[:, None], cache)
            return jnp.argmax(logits[:, -1, :], -1), cache

        self._prefill = jax.jit(prefill_fn)
        self._insert = jax.jit(insert_fn,
                               donate_argnums=(0,) if donate else ())
        self._decode = jax.jit(decode_fn,
                               donate_argnums=(1,) if donate else ())

        self.step = 0
        self.events: List[Dict[str, Any]] = []
        self.watchdog = StepWatchdog()
        self._step_times: List[float] = []
        self._prefill_s = 0.0

    # -- numerics policy ---------------------------------------------------

    def resolution_report(self) -> str:
        """Per-site approximation resolution of the served model (sites
        appear once their prefill/decode traces have run; see
        repro.policy.site_report)."""
        from repro.policy import site_report

        return site_report(self.model.cfg.approx_policy)

    # -- request intake ----------------------------------------------------

    def submit(self, request: Request) -> RequestState:
        if not request.prompt:
            raise ValueError("prompt must be non-empty")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "yields the first token)")
        need = len(request.prompt) + request.max_new_tokens
        if need > self.cfg.max_seq:
            raise ValueError(
                f"request needs {need} cache rows > max_seq "
                f"{self.cfg.max_seq}")
        state = self.scheduler.submit(request, now=time.perf_counter())
        if state.eos_id is None:  # engine default; the Request is not mutated
            state.eos_id = self.cfg.eos_id
        return state

    # -- engine internals ----------------------------------------------------

    def _event(self, kind: str, state: RequestState, slot: int, **kw):
        self.events.append(dict(step=self.step, event=kind,
                                request_id=state.request_id,
                                slot=slot, **kw))

    def _admit(self, admitted: List[RequestState]):
        """One batched prefill for this tick's admissions: pad rows to a
        power of two and prompt length to the bucket, scatter K/V into the
        pool, seed each slot with its first generated token."""
        rpad = _next_pow2(len(admitted))
        spad = min(_round_up(max(len(s.request.prompt) for s in admitted),
                             self.cfg.prefill_bucket), self.cfg.max_seq)
        tokens = np.zeros((rpad, spad), np.int32)
        lens = np.ones((rpad,), np.int32)
        slots = np.full((rpad,), self.cfg.num_slots, np.int32)  # OOB: drop
        for i, state in enumerate(admitted):
            prompt = state.request.prompt
            tokens[i, :len(prompt)] = prompt
            lens[i] = len(prompt)
            slots[i] = state.slot
        t0 = time.perf_counter()
        first, k, v = self._prefill(self.params, jnp.asarray(tokens),
                                    jnp.asarray(lens))
        self.cache = self._insert(self.cache, k, v, jnp.asarray(slots),
                                  jnp.asarray(lens))
        first = np.asarray(first)  # blocks; prefill wall time is honest
        dt = time.perf_counter() - t0
        self._prefill_s += dt
        now = time.perf_counter()
        for i, state in enumerate(admitted):
            state.prefill_s = dt
            state.first_token_time = now
            self._event("admit", state, state.slot,
                        joined_running=state.joined_running_batch)
            self._append_token(state, int(first[i]))

    def _append_token(self, state: RequestState, token: int):
        state.output.append(token)
        self._last_tok[state.slot] = token
        reason = ""
        if state.eos_id is not None and token == state.eos_id:
            reason = "eos"
        elif len(state.output) >= state.request.max_new_tokens:
            reason = "length"
        if reason:
            slot = state.slot  # retire() resets it; event wants the real one
            self.scheduler.retire(slot, reason, self.step,
                                  now=time.perf_counter())
            self._event("retire", state, slot, reason=reason)

    def tick(self) -> bool:
        """One engine iteration: admit -> decode one token for every active
        slot -> retire finished rows. Returns False when fully drained."""
        if not self.scheduler.has_work:
            return False
        now = time.perf_counter()
        for waiting in self.scheduler.waiting:  # trace replay: stamp arrival
            if (waiting.arrival_time == 0.0
                    and waiting.request.arrival_step <= self.step):
                waiting.arrival_time = now
        admitted = self.scheduler.admit(self.step)
        if admitted:
            self._admit(admitted)
        if not self.scheduler.active:  # only future arrivals left
            self.step += 1
            return self.scheduler.has_work
        t0 = time.perf_counter()
        next_tok, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self._last_tok))
        next_tok = np.asarray(next_tok)  # host sync: scheduler needs tokens
        dt = time.perf_counter() - t0
        self._step_times.append(dt)
        self.watchdog.observe(dt)
        self.step += 1
        for slot, state in list(self.scheduler.active.items()):
            self._append_token(state, int(next_tok[slot]))
        return self.scheduler.has_work

    # -- driver --------------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve ``requests`` to completion and report. Single-use: the
        report aggregates everything the engine has done, so reuse would
        fold the previous run's accounting into the next report — build a
        fresh engine (or drive tick()/submit() yourself) instead."""
        if self.scheduler.finished or self._step_times:
            raise RuntimeError(
                "ServeEngine.run() is single-use; build a fresh engine")
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while self.tick():
            pass
        wall = time.perf_counter() - t0
        done = self.scheduler.finished
        generated = sum(len(s.output) for s in done)
        decode_s = float(sum(self._step_times))
        # prefill produces 1 token/request; the rest ride decode steps
        decode_tokens = generated - len(done)
        return ServeReport(
            completed=done,
            wall_s=wall,
            prefill_s=self._prefill_s,
            decode_s=decode_s,
            decode_steps=len(self._step_times),
            generated_tokens=generated,
            tokens_per_s=decode_tokens / decode_s if decode_s else 0.0,
            ttft_p50_ms=_pct([s.ttft_s * 1e3 for s in done], 50),
            ttft_p99_ms=_pct([s.ttft_s * 1e3 for s in done], 99),
            latency_p50_ms=_pct([s.latency_s * 1e3 for s in done], 50),
            latency_p99_ms=_pct([s.latency_s * 1e3 for s in done], 99),
            step_p50_ms=_pct([t * 1e3 for t in self._step_times], 50),
            step_p99_ms=_pct([t * 1e3 for t in self._step_times], 99),
            joined_mid_stream=sum(s.joined_running_batch for s in done),
            straggler_steps=self.watchdog.stragglers,
            events=self.events,
        )
