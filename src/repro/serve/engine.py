"""Synchronous continuous-batching engine over a paged KV cache with
per-request approximation-policy tiers.

Design (replaces the PR 1 fixed-slot pool):

* **Paged KV pool** — one physical page pool for the whole engine:
  ``k/v: (layers, num_blocks * block_size, kv_heads, head_dim)`` with no
  batch dimension. A request owns a *block table* (kv_pool.BlockPool):
  ``ceil((prompt + gen - 1) / block_size)`` pages reserved at admission, so
  short requests no longer pay for ``max_seq`` cells and concurrency is
  bounded by pages, not preallocated rows. Full prompt blocks are
  ref-counted and content-addressed: identical prompt prefixes under the
  same policy share pages (prefix caching) and skip recompute. The old slot
  pool is the degenerate ``block_size == max_seq`` configuration.
* **One jit'd step, block tables inside** — ``DecoderLM.paged_step``
  resolves block tables to gather/scatter indices *inside* the jit'd step:
  decode (S=1) and chunked prefill (S=prefill_chunk) are two fixed shapes of
  the same function, so admission/retirement and table growth never
  recompile.
* **Chunked prefill** — prompts are ingested ``prefill_chunk`` tokens per
  tick, interleaved with decode steps, so a long prompt no longer stalls
  every running stream for its whole prefill; the chunk that reaches the
  prompt's last token yields the first generated token (TTFT).
* **Policy groups** — each request carries an approximation policy (tier
  name from ``EngineConfig.tiers``, a raw spec, an ``ApproxPolicy``, or
  None = the base model's). Requests are batched *by resolved policy*: one
  scheduler + one jit'd step per group (the policy is jit-static, PR 2), so
  mixed free/paid traffic shares steps within a tier and never causes
  cross-tier recompiles. All groups share the physical page pool and the
  model params.
* **Donated buffers** — each group's step donates the pool, which is
  threaded sequentially through the groups' calls within a tick (in-place
  updates; skipped on CPU where jax does not implement donation).
* **Accounting** — per-request TTFT / latency, engine tok/s + step
  percentiles, KV memory utilization (live tokens / pool cells) sampled
  every tick, peak concurrency, and prefix-cache hits (ServeReport).

Greedy (argmax) sampling: deterministic, so paged batched decode is
token-identical to the single-request ``decode_step`` path — asserted in
tests/test_serve.py, including under mixed per-request policies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.policy import ApproxPolicy, parse_policy
from repro.runtime.watchdog import StepWatchdog

from .kv_pool import SENTINEL, BlockPool
from .scheduler import Request, RequestState, Scheduler


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def parse_tiers(spec: str) -> Tuple[Tuple[str, str], ...]:
    """``"free=*=pc3_tr;paid=*/attn/*=exact"`` -> (("free", "*=pc3_tr"), ...).

    Tiers are ';'-separated ``name=policy-spec`` entries (the spec itself
    contains '=' and ',', so only the first '=' splits)."""
    tiers = []
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            continue
        name, sep, policy = item.partition("=")
        if not sep or not name.strip() or not policy.strip():
            raise ValueError(
                f"bad tier entry {item!r}: expected name=policy-spec "
                "(e.g. 'free=*=pc3_tr')")
        tiers.append((name.strip(), policy.strip()))
    return tuple(tiers)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Paged-serving engine configuration.

    ``num_slots`` is the decode-batch width of each policy group (rows of
    its fixed-shape step), decoupled from KV memory: ``num_blocks`` pages of
    ``block_size`` cells bound how many tokens of K/V exist at once.
    ``num_blocks=0`` sizes the pool to ``num_slots * max_seq / block_size``
    — the memory of the old slot pool. ``tiers`` registers named policy
    specs requests can reference (``Request.policy="free"``); see
    :func:`parse_tiers` for the CLI string form.
    """

    num_slots: int = 4          # decode rows per policy group
    max_seq: int = 128          # per-request KV capacity (prompt + gen)
    block_size: int = 16        # KV page size (tokens); max_seq = old slots
    num_blocks: int = 0         # physical pages; 0 = slot-pool-equivalent
    prefill_chunk: int = 16     # prompt tokens ingested per engine tick
    eos_id: Optional[int] = None    # default EOS for requests without one
    tiers: Tuple[Tuple[str, str], ...] = ()  # (name, policy spec) pairs

    def __post_init__(self) -> None:
        # fail at construction with the field named, not as a shape error
        # three layers deep in a jit trace
        for field in ("num_slots", "max_seq", "block_size", "prefill_chunk"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"EngineConfig.{field} must be a positive int "
                    f"(got {v!r})")
        if self.num_blocks < 0:
            raise ValueError(
                f"EngineConfig.num_blocks must be >= 0 "
                f"(0 = auto; got {self.num_blocks})")
        if self.max_seq % self.block_size:
            raise ValueError(
                f"EngineConfig.max_seq ({self.max_seq}) must be a multiple "
                f"of block_size ({self.block_size}): block tables map whole "
                "pages")
        if self.prefill_chunk > self.max_seq:
            raise ValueError(
                f"EngineConfig.prefill_chunk ({self.prefill_chunk}) must be "
                f"<= max_seq ({self.max_seq})")
        if self.prefill_chunk & (self.prefill_chunk - 1):
            raise ValueError(
                f"EngineConfig.prefill_chunk ({self.prefill_chunk}) must be "
                "a power of two (one compiled prefill shape)")
        if isinstance(self.tiers, dict):  # ergonomics: accept a dict
            object.__setattr__(self, "tiers", tuple(self.tiers.items()))
        for name, spec in self.tiers:
            if not isinstance(name, str) or not isinstance(spec, str):
                raise ValueError(
                    f"EngineConfig.tiers entries must be (name, spec) "
                    f"string pairs (got {(name, spec)!r})")

    def validate_for_model(self, model_cfg) -> None:
        """Model/engine compatibility, checked at engine construction with
        the field named — not three layers deep in paged-cache setup.

        A windowed (ring-buffer) cache can never be paged: the ring rolls
        in place while the pool frees whole pages at retirement.
        """
        window = getattr(model_cfg, "window", 0)
        if window:
            raise ValueError(
                f"EngineConfig: ArchConfig.window={window} (on "
                f"{getattr(model_cfg, 'name', '?')!r}) is incompatible with "
                "the paged KV cache — ring buffers roll in place, pages are "
                "freed whole; serve with window=0 (e.g. cfg.smoke(window=0))")

    @property
    def blocks(self) -> int:
        """Physical pool pages (resolves the ``num_blocks=0`` default)."""
        return self.num_blocks or self.num_slots * (self.max_seq
                                                    // self.block_size)

    @property
    def max_blocks_per_seq(self) -> int:
        return self.max_seq // self.block_size


@dataclasses.dataclass
class ServeReport:
    """Aggregate accounting for one engine run.

    Percentiles are unfiltered wall times: on a cold engine the first
    prefill/decode steps are jit-compile-dominated, so small-workload p99
    (and early TTFT) measure compilation — warm the engine or discount the
    first steps when comparing kernels. The straggler counter already
    excludes warmup (StepWatchdog)."""

    completed: List[RequestState]
    wall_s: float
    prefill_s: float
    decode_s: float
    decode_steps: int
    generated_tokens: int
    tokens_per_s: float
    ttft_p50_ms: float
    ttft_p99_ms: float
    latency_p50_ms: float
    latency_p99_ms: float
    step_p50_ms: float
    step_p99_ms: float
    joined_mid_stream: int
    straggler_steps: int
    # paged-KV accounting
    kv_util_mean: float        # live tokens / pool cells, mean over ticks
    kv_util_peak: float
    peak_active_requests: int  # max concurrent admitted requests
    prefix_hits: int           # prompt blocks adopted from the prefix cache
    policy_groups: int         # distinct resolved policies served
    events: List[Dict[str, Any]]

    def summary(self) -> str:
        lines = [
            f"requests {len(self.completed)}  generated "
            f"{self.generated_tokens} tok  wall {self.wall_s:.2f}s  "
            f"({self.tokens_per_s:.1f} tok/s decode)",
            f"prefill {self.prefill_s * 1e3:.1f} ms total;  decode step "
            f"p50 {self.step_p50_ms:.2f} / p99 {self.step_p99_ms:.2f} ms"
            f" over {self.decode_steps} steps"
            f" ({self.straggler_steps} stragglers)",
            f"TTFT p50 {self.ttft_p50_ms:.1f} / p99 {self.ttft_p99_ms:.1f} "
            f"ms;  request latency p50 {self.latency_p50_ms:.1f} / p99 "
            f"{self.latency_p99_ms:.1f} ms",
            f"KV util mean {self.kv_util_mean * 100:.1f}% / peak "
            f"{self.kv_util_peak * 100:.1f}%;  peak concurrency "
            f"{self.peak_active_requests};  {self.prefix_hits} prefix-cache "
            f"block hit(s);  {self.policy_groups} policy group(s)",
            f"{self.joined_mid_stream} request(s) joined the running batch "
            f"mid-stream (continuous batching)",
        ]
        return "\n".join(lines)


class _PolicyGroup:
    """One resolved approximation policy: a model rebound to that policy,
    a scheduler over ``num_slots`` decode rows, one jit'd paged step (two
    compiled shapes: decode S=1, prefill S=prefill_chunk), and the per-row
    host-side metadata (block tables, write offsets, last tokens)."""

    def __init__(self, label: str, policy: Optional[ApproxPolicy], model,
                 cfg: EngineConfig, donate: bool):
        self.label = label
        self.policy = policy
        self.model = model
        self.sched = Scheduler(cfg.num_slots)
        mb = cfg.max_blocks_per_seq
        self.tables = np.full((cfg.num_slots, mb), SENTINEL, np.int32)
        self.last_tok = np.zeros((cfg.num_slots,), np.int32)
        block_size = cfg.block_size

        def step(params, kv, tokens, tables, pos, last_idx):
            cache = dict(kv, block_tables=tables, pos=pos)
            logits, new_kv = model.paged_step(params, tokens, cache,
                                              block_size=block_size)
            last = jnp.take_along_axis(logits, last_idx[:, None, None],
                                       axis=1)  # (R, 1, V) at true length
            return jnp.argmax(last[:, 0, :], -1), new_kv

        self.step_fn = jax.jit(step, donate_argnums=(1,) if donate else ())

    @property
    def prefill_rows(self) -> Dict[int, RequestState]:
        return {s: st for s, st in self.sched.active.items() if st.prefilling}

    @property
    def decode_rows(self) -> Dict[int, RequestState]:
        return {s: st for s, st in self.sched.active.items()
                if not st.prefilling}


class ServeEngine:
    """Drives a DecoderLM-style model (init_paged_cache / paged_step)
    through paged continuous-batching generation. Synchronous: ``run``
    blocks until every submitted request completes."""

    def __init__(self, model, params, cfg: EngineConfig):
        if not hasattr(model, "paged_step"):
            raise TypeError(
                f"{type(model).__name__} has no paged_step(); the serving "
                "engine requires the DecoderLM paged-cache API")
        if hasattr(model, "cfg"):
            cfg.validate_for_model(model.cfg)
        self.model = model
        self.params = params
        self.cfg = cfg
        self.pool = BlockPool(cfg.blocks, cfg.block_size)
        self.kv = model.init_paged_cache(cfg.blocks, cfg.block_size)
        # donation: in-place pool updates (not implemented on CPU — jax
        # would warn and copy anyway)
        self._donate = jax.default_backend() != "cpu"
        self._tiers: Dict[str, ApproxPolicy] = {
            name: parse_policy(spec, name=name) for name, spec in cfg.tiers}
        self.groups: Dict[Optional[ApproxPolicy], _PolicyGroup] = {}
        self._pending_alloc: Dict[int, Tuple[List[int], int]] = {}
        self._next_id = 0

        self.step = 0
        self.events: List[Dict[str, Any]] = []
        self.watchdog = StepWatchdog()
        self._step_times: List[float] = []
        self._prefill_s = 0.0
        self._util_samples: List[float] = []
        self._util_peak = 0.0
        self._peak_active = 0

    # -- numerics policy ---------------------------------------------------

    def resolution_report(self) -> str:
        """Per-site approximation resolution, one section per policy group
        (sites appear once a group's prefill/decode traces have run; see
        repro.policy.site_report)."""
        from repro.policy import site_report

        parts = []
        for group in self.groups.values():
            parts.append(f"== group {group.label} ==")
            parts.append(site_report(group.model.cfg.approx_policy))
        if not parts:
            parts = [site_report(self.model.cfg.approx_policy)]
        return "\n".join(parts)

    # -- request intake ----------------------------------------------------

    def _resolve_policy(self, policy) -> Optional[ApproxPolicy]:
        if policy is None or isinstance(policy, ApproxPolicy):
            return policy
        if isinstance(policy, str):
            if policy in self._tiers:
                return self._tiers[policy]
            if "=" in policy:
                return parse_policy(policy)
            raise ValueError(
                f"unknown policy tier {policy!r}: registered tiers are "
                f"{sorted(self._tiers)} (or pass a spec like '*=pc3_tr')")
        raise TypeError(
            f"Request.policy must be None, a tier name, a spec string, or "
            f"an ApproxPolicy (got {type(policy).__name__})")

    def _group_for(self, policy: Optional[ApproxPolicy]) -> _PolicyGroup:
        # group key ignores the policy's display name: a tier name and the
        # equivalent raw spec resolve to the same jit'd steps + prefix cache
        key = (None if policy is None
               else dataclasses.replace(policy, name=""))
        group = self.groups.get(key)
        if group is None:
            if policy is None:
                label, model = "base", self.model
            else:
                label = policy.name or f"policy_{len(self.groups)}"
                from repro.models.registry import build_model

                model = build_model(self.model.cfg.with_policy(policy))
            group = _PolicyGroup(label, key, model, self.cfg, self._donate)
            self.groups[key] = group
        return group

    def submit(self, request: Request) -> RequestState:
        if not request.prompt:
            raise ValueError("prompt must be non-empty")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "yields the first token)")
        need = len(request.prompt) + request.max_new_tokens
        if need > self.cfg.max_seq:
            raise ValueError(
                f"request needs {need} cache positions > max_seq "
                f"{self.cfg.max_seq}")
        group = self._group_for(self._resolve_policy(request.policy))
        state = group.sched.submit(request, now=time.perf_counter())
        state.request_id = self._next_id  # engine-global, not per-group
        self._next_id += 1
        state.group = group.label
        if state.eos_id is None:  # engine default; the Request is not mutated
            state.eos_id = self.cfg.eos_id
        return state

    # -- engine internals --------------------------------------------------

    def _event(self, kind: str, state: RequestState, slot: int, **kw):
        self.events.append(dict(step=self.step, event=kind,
                                request_id=state.request_id,
                                slot=slot, group=state.group, **kw))

    def _try_reserve(self, group: _PolicyGroup, state: RequestState) -> bool:
        """Admission gate: reserve the request's whole-lifetime KV pages
        (prompt + gen - 1 positions — the final token is never written).
        Reserving up front means an admitted request can always finish."""
        total = len(state.request.prompt) + state.request.max_new_tokens - 1
        alloc = self.pool.allocate(state.request_id, state.request.prompt,
                                   max(total, 1), policy_key=group.policy)
        if alloc is None:
            return False
        self._pending_alloc[state.request_id] = alloc
        return True

    def _admit(self, group: _PolicyGroup, admitted: List[RequestState]):
        for state in admitted:
            table, cached_len = self._pending_alloc.pop(state.request_id)
            group.tables[state.slot] = SENTINEL
            group.tables[state.slot, :len(table)] = table
            state.next_pos = cached_len
            state.cached_len = cached_len
            self._event("admit", state, state.slot,
                        joined_running=state.joined_running_batch,
                        blocks=len(table),
                        cached_blocks=cached_len // self.cfg.block_size)

    def _append_token(self, group: _PolicyGroup, state: RequestState,
                      token: int):
        state.output.append(token)
        group.last_tok[state.slot] = token
        reason = ""
        if state.eos_id is not None and token == state.eos_id:
            reason = "eos"
        elif len(state.output) >= state.request.max_new_tokens:
            reason = "length"
        if reason:
            slot = state.slot  # retire() resets it; event wants the real one
            group.sched.retire(slot, reason, self.step,
                               now=time.perf_counter())
            group.tables[slot] = SENTINEL
            self.pool.free(state.request_id)
            self._event("retire", state, slot, reason=reason)

    def _run_prefill(self, group: _PolicyGroup):
        """One prefill chunk for every row of ``group`` still ingesting its
        prompt; rows that reach the last prompt token emit their first
        generated token. Decode rows are masked out (sentinel tables) so
        their K/V is untouched."""
        rows = group.prefill_rows
        if not rows:
            return
        cfg = self.cfg
        chunk = cfg.prefill_chunk
        r = cfg.num_slots
        tokens = np.zeros((r, chunk), np.int32)
        tables = np.full_like(group.tables, SENTINEL)
        pos = np.zeros((r,), np.int32)
        last_idx = np.zeros((r,), np.int32)
        finishing: Dict[int, RequestState] = {}
        for slot, state in rows.items():
            prompt = state.request.prompt
            piece = prompt[state.next_pos:state.next_pos + chunk]
            tokens[slot, :len(piece)] = piece
            tables[slot] = group.tables[slot]
            pos[slot] = state.next_pos
            last_idx[slot] = len(piece) - 1
            if state.next_pos + len(piece) == len(prompt):
                finishing[slot] = state
            state.next_pos += len(piece)
        t0 = time.perf_counter()
        tok, self.kv = group.step_fn(
            self.params, self.kv, jnp.asarray(tokens), jnp.asarray(tables),
            jnp.asarray(pos), jnp.asarray(last_idx))
        tok = np.asarray(tok)  # blocks; prefill wall time is honest
        dt = time.perf_counter() - t0
        self._prefill_s += dt
        now = time.perf_counter()
        for slot, state in rows.items():
            state.prefill_s += dt
            if slot in finishing:
                state.first_token_time = now
                self.pool.commit_prefix(state.request_id)
                self._append_token(group, state, int(tok[slot]))
            if state.request_id in self.pool:
                self.pool.advance(state.request_id, state.seq_len)

    def _run_decode(self, group: _PolicyGroup):
        """One decode token for every generating row of ``group``; prefill
        and idle rows are masked out (sentinel tables)."""
        rows = group.decode_rows
        if not rows:
            return
        r = self.cfg.num_slots
        tables = np.full_like(group.tables, SENTINEL)
        pos = np.zeros((r,), np.int32)
        for slot, state in rows.items():
            tables[slot] = group.tables[slot]
            pos[slot] = state.seq_len  # write position of the fed-back token
        t0 = time.perf_counter()
        tok, self.kv = group.step_fn(
            self.params, self.kv, jnp.asarray(group.last_tok[:, None]),
            jnp.asarray(tables), jnp.asarray(pos),
            jnp.zeros((r,), jnp.int32))
        tok = np.asarray(tok)  # host sync: scheduler needs tokens
        dt = time.perf_counter() - t0
        self._step_times.append(dt)
        self.watchdog.observe(dt)
        for slot, state in list(rows.items()):
            self._append_token(group, state, int(tok[slot]))
            if state.request_id in self.pool:
                self.pool.advance(state.request_id, state.seq_len)

    def tick(self) -> bool:
        """One engine iteration: admit -> one prefill chunk per ingesting
        row -> one decode token per generating row, per policy group.
        Returns False when fully drained."""
        if not any(g.sched.has_work for g in self.groups.values()):
            return False
        now = time.perf_counter()
        for group in self.groups.values():
            for waiting in group.sched.waiting:  # trace replay: stamp arrival
                if (waiting.arrival_time == 0.0
                        and waiting.request.arrival_step <= self.step):
                    waiting.arrival_time = now
            admitted = group.sched.admit(
                self.step,
                can_admit=lambda st, g=group: self._try_reserve(g, st))
            if admitted:
                self._admit(group, admitted)
        for group in self.groups.values():
            self._run_prefill(group)
        for group in self.groups.values():
            self._run_decode(group)
        active = sum(len(g.sched.active) for g in self.groups.values())
        self._peak_active = max(self._peak_active, active)
        if active:
            util = self.pool.utilization()["pool_util"]
            self._util_samples.append(util)
            self._util_peak = max(self._util_peak, util)
        self.step += 1
        return any(g.sched.has_work for g in self.groups.values())

    # -- driver ------------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve ``requests`` to completion and report. Single-use: the
        report aggregates everything the engine has done, so reuse would
        fold the previous run's accounting into the next report — build a
        fresh engine (or drive tick()/submit() yourself) instead."""
        if self._step_times or any(g.sched.finished
                                   for g in self.groups.values()):
            raise RuntimeError(
                "ServeEngine.run() is single-use; build a fresh engine")
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while self.tick():
            pass
        wall = time.perf_counter() - t0
        done = [s for g in self.groups.values() for s in g.sched.finished]
        done.sort(key=lambda s: s.request_id)
        generated = sum(len(s.output) for s in done)
        decode_s = float(sum(self._step_times))
        # prefill produces 1 token/request; the rest ride decode steps
        decode_tokens = generated - len(done)
        return ServeReport(
            completed=done,
            wall_s=wall,
            prefill_s=self._prefill_s,
            decode_s=decode_s,
            decode_steps=len(self._step_times),
            generated_tokens=generated,
            tokens_per_s=decode_tokens / decode_s if decode_s else 0.0,
            ttft_p50_ms=_pct([s.ttft_s * 1e3 for s in done], 50),
            ttft_p99_ms=_pct([s.ttft_s * 1e3 for s in done], 99),
            latency_p50_ms=_pct([s.latency_s * 1e3 for s in done], 50),
            latency_p99_ms=_pct([s.latency_s * 1e3 for s in done], 99),
            step_p50_ms=_pct([t * 1e3 for t in self._step_times], 50),
            step_p99_ms=_pct([t * 1e3 for t in self._step_times], 99),
            joined_mid_stream=sum(s.joined_running_batch for s in done),
            straggler_steps=self.watchdog.stragglers,
            kv_util_mean=(float(np.mean(self._util_samples))
                          if self._util_samples else 0.0),
            kv_util_peak=self._util_peak,
            peak_active_requests=self._peak_active,
            prefix_hits=self.pool.prefix_hits,
            policy_groups=len(self.groups),
            events=self.events,
        )
