"""Continuous-batching engine over a paged KV cache: async tick loop,
tensor-parallel sharded steps, per-request policy tiers, preemption/swap.

Design (PR 1 slot pool -> PR 6 paged pool -> this: sharded + async):

* **Paged KV pool** — one physical page pool for the whole engine:
  ``k/v: (layers, num_blocks * block_size, kv_heads, head_dim)`` with no
  batch dimension. A request owns a *block table* (kv_pool.BlockPool);
  full prompt blocks are ref-counted and content-addressed (prefix
  caching). The old slot pool is the degenerate ``block_size == max_seq``
  configuration.
* **One jit'd step, block tables inside** — ``DecoderLM.paged_step``
  resolves block tables to gather/scatter indices *inside* the jit'd step:
  decode (S=1) and chunked prefill (S=prefill_chunk) are two fixed shapes
  of the same function, so admission/retirement and table growth never
  recompile.
* **Tensor-parallel sharding** — pass ``mesh=`` (with a ``model`` axis) and
  the engine lays params out with the repo's serve Sharder rules, splits
  the page pool's kv-heads dim over the same axis
  (``DecoderLM.paged_cache_axes``), and traces every group step under the
  sharder: the paged scatter/gather/attend runs as a head-local shard_map
  (models/layers.py), so block-table traffic never crosses shards.
  ``EngineConfig.shards`` documents the layout; blocks and num_slots must
  divide by it (daism-lint SRV007) or GSPMD silently replicates the pool.
* **Async tick loop** — each tick *launches* every group's prefill + decode
  steps without blocking, then does the host-side work (arrival stamping,
  admission + page reservation — step N+1's batch assembly) while the
  device chews, and only then blocks on the token fetch. Fetch-blocked time
  is accounted per run (``ServeReport.host_idle_frac``); ``overlap=False``
  fetches immediately after each launch — the synchronous baseline the
  idle-fraction claim in benchmarks/serve_bench.py is measured against.
* **Preemption/swap** — ``preempt=True`` switches admission from
  whole-lifetime page reservation to optimistic prompt-only allocation
  with on-demand ``extend`` at every block boundary. Under page exhaustion
  the engine swaps the lowest-priority (tie: youngest) *decoding* request
  out to a host-side buffer — an exact gather of its pages — frees its
  blocks and rows, and resumes it later token-identically (scatter back
  through a fresh table; greedy decode continues from its last token).
  The swap buffer holds at most ``swap_blocks`` pages (0 = one full
  request, ``max_blocks_per_seq``); undersized buffers stall instead of
  deadlocking (daism-lint SRV008 warns). Admission may preempt only
  strictly-lower-priority victims; extension of a running request may
  preempt equals (LIFO), so older requests finish.
* **Policy groups** — each request carries an approximation policy (tier
  name from ``EngineConfig.tiers``, a raw spec, an ``ApproxPolicy``, or
  None = the base model's). Requests are batched *by resolved policy*: one
  scheduler + one jit'd step per group; all groups share the physical page
  pool and the model params.
* **Self-speculative decoding** — ``spec_draft``/``spec_k`` chain
  ``spec_k`` S=1 draft steps under a cheap approximate policy (the same
  weights — DAISM's approximate multiplier is a free weight-sharing draft
  model) and verify all candidates in one batched S=spec_k+1 step under
  the group's own policy (``DecoderLM.paged_verify_step``). Greedy
  accept/reject + bonus token keeps the output token-identical to plain
  decode; drafted K/V is scratch — the verify overwrites the window in
  place, the pool truncates pages past the accepted length, and a
  per-group acceptance EWMA turns speculation off where it doesn't pay.
* **Accounting** — per-request TTFT / latency, inter-token gap
  percentiles, engine tok/s + step percentiles, KV utilization, peak
  concurrency, prefix-cache hits, preemptions/resumes, host idle time.

Greedy (argmax) sampling: deterministic, so paged batched decode is
token-identical to the single-request ``decode_step`` path — asserted in
tests/test_serve.py, including under mixed per-request policies and across
a preempt/swap/resume cycle.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.policy import ApproxPolicy, parse_policy
from repro.runtime.watchdog import StepWatchdog

from .kv_pool import SENTINEL, BlockPool, blocks_needed
from .scheduler import Request, RequestState, Scheduler


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def parse_tiers(spec: str) -> Tuple[Tuple[str, str], ...]:
    """``"free=*=pc3_tr;paid=*/attn/*=exact"`` -> (("free", "*=pc3_tr"), ...).

    Tiers are ';'-separated ``name=policy-spec`` entries (the spec itself
    contains '=' and ',', so only the first '=' splits)."""
    tiers = []
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            continue
        name, sep, policy = item.partition("=")
        if not sep or not name.strip() or not policy.strip():
            raise ValueError(
                f"bad tier entry {item!r}: expected name=policy-spec "
                "(e.g. 'free=*=pc3_tr')")
        tiers.append((name.strip(), policy.strip()))
    return tuple(tiers)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Paged-serving engine configuration.

    ``num_slots`` is the decode-batch width of each policy group (rows of
    its fixed-shape step), decoupled from KV memory: ``num_blocks`` pages of
    ``block_size`` cells bound how many tokens of K/V exist at once.
    ``num_blocks=0`` sizes the pool to ``num_slots * max_seq / block_size``
    — the memory of the old slot pool. ``tiers`` registers named policy
    specs requests can reference (``Request.policy="free"``); see
    :func:`parse_tiers` for the CLI string form.

    ``shards`` declares the mesh serving-axis (``model``) size the engine
    is laid out for — pass the matching mesh to ``ServeEngine``; blocks
    and num_slots must divide by it. ``preempt`` switches whole-lifetime
    page reservation to optimistic allocation + swap-out under exhaustion
    (``swap_blocks`` pages of host buffer, 0 = one full request).
    ``overlap=False`` disables the async tick loop (synchronous baseline).

    ``spec_draft`` + ``spec_k`` enable self-speculative decoding: every
    decode tick drafts ``spec_k`` tokens per row under the (cheap,
    weight-sharing) ``spec_draft`` policy — a tier name or raw spec — then
    one batched verify step under the group's own policy accepts the
    longest matching prefix plus a bonus token (token-identical to plain
    greedy decode). A per-group EWMA of the draft acceptance rate
    auto-disables speculation below ``spec_min_accept`` so hostile traffic
    never pays more than one wasted draft window per group.
    """

    num_slots: int = 4          # decode rows per policy group
    max_seq: int = 128          # per-request KV capacity (prompt + gen)
    block_size: int = 16        # KV page size (tokens); max_seq = old slots
    num_blocks: int = 0         # physical pages; 0 = slot-pool-equivalent
    prefill_chunk: int = 16     # prompt tokens ingested per engine tick
    eos_id: Optional[int] = None    # default EOS for requests without one
    tiers: Tuple[Tuple[str, str], ...] = ()  # (name, policy spec) pairs
    shards: int = 1             # mesh 'model'-axis size (tensor parallel)
    preempt: bool = False       # optimistic admission + swap on exhaustion
    swap_blocks: int = 0        # host swap buffer pages (0 = one request)
    overlap: bool = True        # async tick loop (False = sync baseline)
    spec_draft: str = ""        # draft policy (tier name | spec; "" = off)
    spec_k: int = 0             # draft tokens per verify step (0 = off)
    spec_min_accept: float = 0.25   # EWMA accept floor before auto-disable

    def __post_init__(self) -> None:
        # fail at construction with the field named, not as a shape error
        # three layers deep in a jit trace
        for field in ("num_slots", "max_seq", "block_size", "prefill_chunk",
                      "shards"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"EngineConfig.{field} must be a positive int "
                    f"(got {v!r})")
        if self.num_blocks < 0:
            raise ValueError(
                f"EngineConfig.num_blocks must be >= 0 "
                f"(0 = auto; got {self.num_blocks})")
        if self.swap_blocks < 0:
            raise ValueError(
                f"EngineConfig.swap_blocks must be >= 0 "
                f"(0 = one request's worth; got {self.swap_blocks})")
        if self.max_seq % self.block_size:
            raise ValueError(
                f"EngineConfig.max_seq ({self.max_seq}) must be a multiple "
                f"of block_size ({self.block_size}): block tables map whole "
                "pages")
        if self.prefill_chunk > self.max_seq:
            raise ValueError(
                f"EngineConfig.prefill_chunk ({self.prefill_chunk}) must be "
                f"<= max_seq ({self.max_seq})")
        if self.prefill_chunk & (self.prefill_chunk - 1):
            raise ValueError(
                f"EngineConfig.prefill_chunk ({self.prefill_chunk}) must be "
                "a power of two (one compiled prefill shape)")
        if not isinstance(self.spec_k, int) or self.spec_k < 0:
            raise ValueError(
                f"EngineConfig.spec_k must be an int >= 0 "
                f"(0 = speculation off; got {self.spec_k!r})")
        if not isinstance(self.spec_draft, str):
            raise ValueError(
                "EngineConfig.spec_draft must be a tier name or policy spec "
                f"string (got {type(self.spec_draft).__name__})")
        if bool(self.spec_k) != bool(self.spec_draft):
            raise ValueError(
                "EngineConfig: spec_draft and spec_k enable speculative "
                "decoding together — set both (spec_draft=<tier|spec>, "
                f"spec_k>=1) or neither (got spec_draft={self.spec_draft!r}, "
                f"spec_k={self.spec_k})")
        if self.spec_k >= self.max_seq:
            raise ValueError(
                f"EngineConfig.spec_k ({self.spec_k}) must be < max_seq "
                f"({self.max_seq}): the verify window is spec_k+1 positions "
                "of one request's cache")
        if not 0.0 <= self.spec_min_accept <= 1.0:
            raise ValueError(
                f"EngineConfig.spec_min_accept must be in [0, 1] "
                f"(got {self.spec_min_accept})")
        if isinstance(self.tiers, dict):  # ergonomics: accept a dict
            object.__setattr__(self, "tiers", tuple(self.tiers.items()))
        for name, spec in self.tiers:
            if not isinstance(name, str) or not isinstance(spec, str):
                raise ValueError(
                    f"EngineConfig.tiers entries must be (name, spec) "
                    f"string pairs (got {(name, spec)!r})")

    def validate_for_model(self, model_cfg) -> None:
        """Model/engine compatibility, checked at engine construction with
        the field named — not three layers deep in paged-cache setup.

        A windowed (ring-buffer) cache can never be paged: the ring rolls
        in place while the pool frees whole pages at retirement.
        """
        window = getattr(model_cfg, "window", 0)
        if window:
            raise ValueError(
                f"EngineConfig: ArchConfig.window={window} (on "
                f"{getattr(model_cfg, 'name', '?')!r}) is incompatible with "
                "the paged KV cache — ring buffers roll in place, pages are "
                "freed whole; serve with window=0 (e.g. cfg.smoke(window=0))")

    @property
    def blocks(self) -> int:
        """Physical pool pages (resolves the ``num_blocks=0`` default)."""
        return self.num_blocks or self.num_slots * (self.max_seq
                                                    // self.block_size)

    @property
    def max_blocks_per_seq(self) -> int:
        return self.max_seq // self.block_size

    @property
    def swap_capacity(self) -> int:
        """Host swap buffer size in pages (0 when preemption is off)."""
        if not self.preempt:
            return 0
        return self.swap_blocks or self.max_blocks_per_seq


@dataclasses.dataclass
class ServeReport:
    """Aggregate accounting for one engine run.

    Percentiles are unfiltered wall times: on a cold engine the first
    prefill/decode steps are jit-compile-dominated, so small-workload p99
    (and early TTFT) measure compilation — warm the engine or discount the
    first steps when comparing kernels. The straggler counter already
    excludes warmup (StepWatchdog). In async mode (``overlap=True``) step
    times span launch -> fetch, so they include the overlapped host work;
    ``host_idle_s`` counts only the time actually *blocked* on device
    results — the number the async loop exists to shrink."""

    completed: List[RequestState]
    wall_s: float
    prefill_s: float
    decode_s: float
    decode_steps: int
    generated_tokens: int
    tokens_per_s: float
    ttft_p50_ms: float
    ttft_p95_ms: float
    ttft_p99_ms: float
    latency_p50_ms: float
    latency_p95_ms: float
    latency_p99_ms: float
    tok_lat_p50_ms: float      # inter-token gap percentiles (per request)
    tok_lat_p95_ms: float
    tok_lat_p99_ms: float
    step_p50_ms: float
    step_p99_ms: float
    joined_mid_stream: int
    straggler_steps: int
    # async tick-loop accounting
    ticks: int                 # engine iterations driven
    host_idle_s: float         # wall time blocked on device token fetches
    host_idle_frac: float      # host_idle_s / wall_s
    # paged-KV accounting
    kv_util_mean: float        # live tokens / pool cells, mean over ticks
    kv_util_peak: float
    peak_active_requests: int  # max concurrent admitted requests
    prefix_hits: int           # prompt blocks adopted from the prefix cache
    preemptions: int           # requests swapped out under page exhaustion
    resumes: int               # swapped requests restored and continued
    policy_groups: int         # distinct resolved policies served
    shards: int                # mesh serving-axis size (1 = single device)
    events: List[Dict[str, Any]]
    # speculative-decoding accounting (all zero when spec is off)
    spec_steps: int = 0        # batched verify steps launched
    spec_drafted: int = 0      # draft tokens proposed (rows x spec_k)
    spec_accepted: int = 0     # drafts accepted by the verify step
    spec_accept_rate: float = 0.0   # accepted / drafted
    spec_tokens_per_step: float = 0.0  # emitted per row-verify (incl. bonus)
    spec_disabled_groups: int = 0  # groups auto-disabled by the EWMA floor

    def summary(self) -> str:
        lines = [
            f"requests {len(self.completed)}  generated "
            f"{self.generated_tokens} tok  wall {self.wall_s:.2f}s  "
            f"({self.tokens_per_s:.1f} tok/s decode)",
            f"prefill {self.prefill_s * 1e3:.1f} ms total;  decode step "
            f"p50 {self.step_p50_ms:.2f} / p99 {self.step_p99_ms:.2f} ms"
            f" over {self.decode_steps} steps"
            f" ({self.straggler_steps} stragglers)",
            f"TTFT p50 {self.ttft_p50_ms:.1f} / p95 {self.ttft_p95_ms:.1f} "
            f"/ p99 {self.ttft_p99_ms:.1f} ms;  request latency p50 "
            f"{self.latency_p50_ms:.1f} / p95 {self.latency_p95_ms:.1f} / "
            f"p99 {self.latency_p99_ms:.1f} ms",
            f"inter-token p50 {self.tok_lat_p50_ms:.2f} / p95 "
            f"{self.tok_lat_p95_ms:.2f} / p99 {self.tok_lat_p99_ms:.2f} ms",
            f"host idle {self.host_idle_s * 1e3:.1f} ms "
            f"({self.host_idle_frac * 100:.1f}% of wall) over {self.ticks} "
            f"ticks;  {self.shards} shard(s)",
            f"KV util mean {self.kv_util_mean * 100:.1f}% / peak "
            f"{self.kv_util_peak * 100:.1f}%;  peak concurrency "
            f"{self.peak_active_requests};  {self.prefix_hits} prefix-cache "
            f"block hit(s);  {self.policy_groups} policy group(s)",
            f"{self.preemptions} preemption(s) / {self.resumes} resume(s);  "
            f"{self.joined_mid_stream} request(s) joined the running batch "
            f"mid-stream (continuous batching)",
        ]
        if self.spec_steps:
            lines.append(
                f"speculative: {self.spec_steps} verify step(s), "
                f"{self.spec_accepted}/{self.spec_drafted} drafts accepted "
                f"({self.spec_accept_rate * 100:.0f}%), "
                f"{self.spec_tokens_per_step:.2f} tokens/verify-step"
                + (f";  {self.spec_disabled_groups} group(s) auto-disabled"
                   if self.spec_disabled_groups else ""))
        return "\n".join(lines)


class _PolicyGroup:
    """One resolved approximation policy: a model rebound to that policy,
    a scheduler over ``num_slots`` decode rows, one jit'd paged step (fixed
    compiled shapes: decode S=1, prefill S=prefill_chunk, and — when
    speculation is on — verify S=spec_k+1), and the per-row host-side
    metadata (block tables, write offsets, last tokens)."""

    def __init__(self, label: str, policy: Optional[ApproxPolicy], model,
                 cfg: EngineConfig, donate: bool, sharder=None):
        self.label = label
        self.policy = policy
        self.model = model
        self.sched = Scheduler(cfg.num_slots)
        mb = cfg.max_blocks_per_seq
        self.tables = np.full((cfg.num_slots, mb), SENTINEL, np.int32)
        self.last_tok = np.zeros((cfg.num_slots,), np.int32)
        block_size = cfg.block_size
        # speculative-decode state: eligibility (the engine disables groups
        # whose policy *is* the draft policy) and the dynamic-k controller's
        # acceptance EWMA (spec_on drops to False below the floor)
        self.spec_on = False
        self.spec_ewma: Optional[float] = None
        self.spec_obs = 0

        def scope():
            if sharder is None:
                return contextlib.nullcontext()
            from repro.parallel.sharding import use_sharder
            return use_sharder(sharder)

        def step(params, kv, tokens, tables, pos, last_idx):
            # traced under the engine's sharder (when meshed) so the paged
            # attention takes the head-local shard_map path and every
            # constrain() in the layer stack sees the mesh
            with scope():
                cache = dict(kv, block_tables=tables, pos=pos)
                logits, new_kv = model.paged_step(params, tokens, cache,
                                                  block_size=block_size)
                last = jnp.take_along_axis(logits, last_idx[:, None, None],
                                           axis=1)  # (R, 1, V) at true length
                return jnp.argmax(last[:, 0, :], -1), new_kv

        self.step_fn = jax.jit(step, donate_argnums=(1,) if donate else ())

        self.verify_fn = None
        if cfg.spec_k:
            def verify(params, kv, tokens, tables, pos):
                # the S=spec_k+1 shape of the same paged-step trace family,
                # under the *group's own* policy: acceptance is judged
                # against exactly what plain decode would have emitted
                with scope():
                    cache = dict(kv, block_tables=tables, pos=pos)
                    return model.paged_verify_step(params, tokens, cache,
                                                   block_size=block_size)

            self.verify_fn = jax.jit(verify,
                                     donate_argnums=(1,) if donate else ())

    @property
    def prefill_rows(self) -> Dict[int, RequestState]:
        return {s: st for s, st in self.sched.active.items() if st.prefilling}

    @property
    def decode_rows(self) -> Dict[int, RequestState]:
        return {s: st for s, st in self.sched.active.items()
                if not st.prefilling}


class ServeEngine:
    """Drives a DecoderLM-style model (init_paged_cache / paged_step)
    through paged continuous-batching generation, optionally sharded over
    ``mesh`` (tensor-parallel serving: pass a mesh with a ``model`` axis
    matching ``cfg.shards``). ``run`` blocks until every submitted request
    completes; the tick loop itself overlaps host scheduling with the
    in-flight device step unless ``cfg.overlap`` is False."""

    # ticks with active/arrived work but no launches and no admissions
    # before the engine declares a livelock (undersized swap buffer)
    _STUCK_TICKS = 1000
    # dynamic-k controller: EWMA smoothing of the per-verify acceptance
    # rate, and how many verify steps to observe before the
    # ``spec_min_accept`` floor may disable a group's speculation
    _SPEC_EWMA_ALPHA = 0.4
    _SPEC_WARMUP = 4

    def __init__(self, model, params, cfg: EngineConfig, mesh=None):
        if not hasattr(model, "paged_step"):
            raise TypeError(
                f"{type(model).__name__} has no paged_step(); the serving "
                "engine requires the DecoderLM paged-cache API")
        if hasattr(model, "cfg"):
            cfg.validate_for_model(model.cfg)
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.shards = 1
        self.sharder = None
        if mesh is not None and "model" in mesh.axis_names:
            self.shards = int(mesh.shape["model"])
        if cfg.shards > 1 and self.shards != cfg.shards:
            have = (f"a {self.shards}-way 'model' axis" if mesh is not None
                    else "no mesh")
            raise ValueError(
                f"EngineConfig.shards={cfg.shards} but the engine got "
                f"{have}; pass ServeEngine(..., mesh=...) with a matching "
                "'model' mesh axis")
        if self.shards > 1 and (cfg.blocks % self.shards
                                or cfg.num_slots % self.shards):
            raise ValueError(
                f"EngineConfig: blocks={cfg.blocks} and num_slots="
                f"{cfg.num_slots} must both be divisible by the mesh "
                f"serving-axis size ({self.shards}): uneven banks make "
                "GSPMD silently replicate the pool instead of sharding it "
                "(daism-lint SRV007)")
        self.pool = BlockPool(cfg.blocks, cfg.block_size)
        self.kv = model.init_paged_cache(cfg.blocks, cfg.block_size)
        # donation: in-place pool updates (not implemented on CPU — jax
        # would warn and copy anyway)
        self._donate = jax.default_backend() != "cpu"
        if mesh is not None:
            from repro.models.module import axes_tree
            from repro.parallel.sharding import (Sharder, base_rules,
                                                 tree_shardings, use_sharder)
            self.sharder = Sharder(
                mesh, base_rules("pod" in mesh.axis_names, serve=True))
            with use_sharder(self.sharder):
                shapes, axes = model.init(jax.random.PRNGKey(0),
                                          abstract=True)
            shardings = tree_shardings(self.sharder, shapes,
                                       axes_tree(shapes, axes))
            params = jax.device_put(params, shardings)
            pool_axes = getattr(model, "paged_cache_axes",
                                lambda: ("layers", None, "act_kv_heads",
                                         None))()
            self.kv = {
                n: jax.device_put(a, self.sharder.sharding(pool_axes,
                                                           a.shape))
                for n, a in self.kv.items()}
        self.params = params
        self._tiers: Dict[str, ApproxPolicy] = {
            name: parse_policy(spec, name=name) for name, spec in cfg.tiers}

        # self-speculative decoding: one draft model (the engine's weights
        # rebound to the cheap draft policy) + one jit'd S=1 draft step
        # shared by every eligible group — the verify step is per-group
        self._spec_key: Optional[ApproxPolicy] = None
        self._draft_step = None
        if cfg.spec_k:
            draft_policy = self._resolve_policy(cfg.spec_draft)
            self._spec_key = dataclasses.replace(draft_policy, name="")
            from repro.models.registry import build_model
            draft_model = build_model(
                self.model.cfg.with_policy(draft_policy))
            self._draft_model = draft_model
            sharder = self.sharder

            def dscope():
                if sharder is None:
                    return contextlib.nullcontext()
                from repro.parallel.sharding import use_sharder
                return use_sharder(sharder)

            def draft(params, kv, tokens, tables, pos):
                with dscope():
                    cache = dict(kv, block_tables=tables, pos=pos)
                    logits, new_kv = draft_model.paged_step(
                        params, tokens, cache, block_size=cfg.block_size)
                return jnp.argmax(logits[:, 0, :], -1), new_kv

            self._draft_step = jax.jit(
                draft, donate_argnums=(1,) if self._donate else ())

        self.groups: Dict[Optional[ApproxPolicy], _PolicyGroup] = {}
        self._pending_alloc: Dict[int, Tuple[List[int], int]] = {}
        self._next_id = 0

        # fixed-shape swap steps (preemption): exact page gather/scatter
        cells = cfg.blocks * cfg.block_size
        bs = cfg.block_size

        def _swap_idx(table):
            base = jnp.where(table < 0, cells, table * bs)
            return (base[:, None] + jnp.arange(bs)).reshape(-1)

        def swap_out(kv, table):  # table (MB,) int32, SENTINEL-padded
            idx = jnp.minimum(_swap_idx(table), cells - 1)
            return (jnp.take(kv["k"], idx, axis=1),
                    jnp.take(kv["v"], idx, axis=1))

        def swap_in(kv, table, k, v):  # unmapped entries >= cells: dropped
            idx = _swap_idx(table)
            return dict(kv,
                        k=kv["k"].at[:, idx].set(k, mode="drop"),
                        v=kv["v"].at[:, idx].set(v, mode="drop"))

        self._swap_out = jax.jit(swap_out)
        self._swap_in = jax.jit(swap_in)
        self._swapped_blocks = 0

        self.step = 0
        self.events: List[Dict[str, Any]] = []
        self.watchdog = StepWatchdog()
        self._step_times: List[float] = []
        self._prefill_s = 0.0
        self._idle_s = 0.0
        self._tok_gaps: List[float] = []
        self._util_samples: List[float] = []
        self._util_peak = 0.0
        self._peak_active = 0
        self._preemptions = 0
        self._resumes = 0
        self._stuck_ticks = 0
        # speculative-decoding accounting
        self._spec_steps = 0       # batched verify launches
        self._spec_row_steps = 0   # (row, verify) pairs folded back
        self._spec_drafted = 0
        self._spec_accepted = 0
        self._spec_emitted = 0     # tokens emitted by verify (incl. bonus)
        self._spec_disabled = 0    # groups shut off by the EWMA floor

    # -- numerics policy ---------------------------------------------------

    def resolution_report(self) -> str:
        """Per-site approximation resolution, one section per policy group
        (sites appear once a group's prefill/decode traces have run; see
        repro.policy.site_report)."""
        from repro.policy import site_report

        parts = []
        for group in self.groups.values():
            parts.append(f"== group {group.label} ==")
            parts.append(site_report(group.model.cfg.approx_policy))
        if not parts:
            parts = [site_report(self.model.cfg.approx_policy)]
        return "\n".join(parts)

    # -- request intake ----------------------------------------------------

    def _resolve_policy(self, policy) -> Optional[ApproxPolicy]:
        if policy is None or isinstance(policy, ApproxPolicy):
            return policy
        if isinstance(policy, str):
            if policy in self._tiers:
                return self._tiers[policy]
            if "=" in policy:
                return parse_policy(policy)
            raise ValueError(
                f"unknown policy tier {policy!r}: registered tiers are "
                f"{sorted(self._tiers)} (or pass a spec like '*=pc3_tr')")
        raise TypeError(
            f"Request.policy must be None, a tier name, a spec string, or "
            f"an ApproxPolicy (got {type(policy).__name__})")

    def _spec_eligible(self, key: Optional[ApproxPolicy]) -> bool:
        """Speculation is per-group: a group whose resolved policy *is* the
        draft policy would verify with the numerics it drafted with — a
        pure loss (daism-lint SRV009 flags the engine-wide analogue)."""
        if self._spec_key is None:
            return False
        group_policy = key
        if group_policy is None:  # base group: the model's own policy
            group_policy = getattr(self.model.cfg, "approx_policy", None)
        if group_policy is None:
            return True
        return dataclasses.replace(group_policy, name="") != self._spec_key

    def _group_for(self, policy: Optional[ApproxPolicy]) -> _PolicyGroup:
        # group key ignores the policy's display name: a tier name and the
        # equivalent raw spec resolve to the same jit'd steps + prefix cache
        key = (None if policy is None
               else dataclasses.replace(policy, name=""))
        group = self.groups.get(key)
        if group is None:
            if policy is None:
                label, model = "base", self.model
            else:
                label = policy.name or f"policy_{len(self.groups)}"
                from repro.models.registry import build_model

                model = build_model(self.model.cfg.with_policy(policy))
            group = _PolicyGroup(label, key, model, self.cfg, self._donate,
                                 self.sharder)
            group.spec_on = self._spec_eligible(key)
            self.groups[key] = group
        return group

    def submit(self, request: Request) -> RequestState:
        if not request.prompt:
            raise ValueError("prompt must be non-empty")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "yields the first token)")
        need = len(request.prompt) + request.max_new_tokens
        if need > self.cfg.max_seq:
            raise ValueError(
                f"request needs {need} cache positions > max_seq "
                f"{self.cfg.max_seq}")
        group = self._group_for(self._resolve_policy(request.policy))
        state = group.sched.submit(request, now=time.perf_counter())
        state.request_id = self._next_id  # engine-global, not per-group
        self._next_id += 1
        state.group = group.label
        if state.eos_id is None:  # engine default; the Request is not mutated
            state.eos_id = self.cfg.eos_id
        return state

    # -- engine internals --------------------------------------------------

    def _event(self, kind: str, state: RequestState, slot: int, **kw):
        self.events.append(dict(step=self.step, event=kind,
                                request_id=state.request_id,
                                slot=slot, group=state.group, **kw))

    def _try_reserve(self, group: _PolicyGroup, state: RequestState,
                     allow_preempt: bool = False) -> bool:
        """Admission gate. Reservation policy depends on the engine mode:
        whole lifetime (prompt + gen - 1; an admitted request can always
        finish) by default, prompt-only when preemption is on (optimistic —
        decode extends on demand and swaps victims out under exhaustion),
        written-length for a resuming swapped request. With
        ``allow_preempt``, strictly-lower-priority running requests are
        swapped out to make room."""
        req = state.request
        if state.swap is not None:
            total = state.seq_len        # resume: cover what was written
        elif self.cfg.preempt:
            total = len(req.prompt)      # optimistic: prompt only
        else:
            total = len(req.prompt) + req.max_new_tokens - 1
        args = (state.request_id, req.prompt, max(total, 1))
        alloc = self.pool.allocate(*args, policy_key=group.policy)
        while alloc is None and allow_preempt:
            victim = self._pick_victim(exclude_id=state.request_id,
                                       max_priority=req.priority)
            if victim is None:
                break
            self._preempt(*victim)
            alloc = self.pool.allocate(*args, policy_key=group.policy)
        if alloc is None:
            return False
        self._pending_alloc[state.request_id] = alloc
        return True

    def _admit(self, group: _PolicyGroup, admitted: List[RequestState]):
        for state in admitted:
            table, cached_len = self._pending_alloc.pop(state.request_id)
            group.tables[state.slot] = SENTINEL
            group.tables[state.slot, :len(table)] = table
            if state.swap is not None:
                self._swap_restore(group, state, table)
                continue
            state.next_pos = cached_len
            state.cached_len = cached_len
            self._event("admit", state, state.slot,
                        joined_running=state.joined_running_batch,
                        blocks=len(table),
                        cached_blocks=cached_len // self.cfg.block_size)

    # -- preemption / swap -------------------------------------------------

    def _pick_victim(self, exclude_id: Optional[int] = None,
                     max_priority: Optional[int] = None):
        """Lowest-priority (tie: youngest admission) *decoding* request
        whose pages fit in the remaining swap buffer. Prefilling rows are
        never preempted — their pages are mid-write. Returns
        ``(group, slot, state)`` or None."""
        best = None
        free_swap = self.cfg.swap_capacity - self._swapped_blocks
        for group in self.groups.values():
            for slot, st in group.sched.active.items():
                if st.prefilling or st.request_id == exclude_id:
                    continue
                if (max_priority is not None
                        and st.request.priority >= max_priority):
                    continue
                if blocks_needed(st.seq_len,
                                 self.cfg.block_size) > free_swap:
                    continue
                key = (st.request.priority, -st.admit_step, -st.request_id)
                if best is None or key < best[0]:
                    best = (key, group, slot, st)
        return None if best is None else best[1:]

    def _preempt(self, group: _PolicyGroup, slot: int, state: RequestState):
        """Swap ``state`` out: exact gather of its written pages into the
        host buffer, then free its blocks and decode row. Only called while
        no device step is in flight (launch phase / post-apply admission),
        so ``self.kv`` is the settled pool."""
        n_blocks = blocks_needed(state.seq_len, self.cfg.block_size)
        table = np.full((self.cfg.max_blocks_per_seq,), SENTINEL, np.int32)
        table[:n_blocks] = group.tables[slot, :n_blocks]
        k, v = self._swap_out(self.kv, jnp.asarray(table))
        state.swap = {"k": np.asarray(k), "v": np.asarray(v),
                      "blocks": n_blocks}
        self._swapped_blocks += n_blocks
        self.pool.free(state.request_id)
        group.sched.requeue(slot)
        group.tables[slot] = SENTINEL
        self._preemptions += 1
        self._event("preempt", state, slot, blocks=n_blocks)

    def _swap_restore(self, group: _PolicyGroup, state: RequestState,
                      table: List[int]):
        """Scatter a resuming request's swapped pages through its fresh
        block table — bit-exact restore, so greedy decode continues
        token-identically from its last emitted token."""
        swap, state.swap = state.swap, None
        n_old = swap["blocks"]
        self._swapped_blocks -= n_old
        # only the written blocks are restored; any extra freshly-allocated
        # blocks cover future positions and are written by decode itself
        t = np.full((self.cfg.max_blocks_per_seq,), SENTINEL, np.int32)
        t[:n_old] = table[:n_old]
        self.kv = self._swap_in(self.kv, jnp.asarray(t),
                                jnp.asarray(swap["k"]),
                                jnp.asarray(swap["v"]))
        self.pool.advance(state.request_id, state.seq_len)
        self.pool.commit_prefix(state.request_id)
        group.last_tok[state.slot] = state.output[-1]
        self._resumes += 1
        self._event("resume", state, state.slot, blocks=len(table))

    def _ensure_blocks(self, group: _PolicyGroup, state: RequestState,
                       ahead: int = 0) -> bool:
        """Grow the row's table to cover its next token write (a no-op
        inside the reservation); under preemption, swap victims out on
        exhaustion. False = the row stalls this tick (no decode step).

        ``ahead`` asks for extra speculative coverage (the draft window
        past the next write). It is best-effort and never evicts anyone:
        if the pool can't cover it, the row falls back to plain
        single-token growth — the verify caps acceptance at whatever
        coverage the row actually got — and only *that* baseline need may
        preempt victims."""
        if ahead:
            table = self.pool.extend(state.request_id,
                                     state.seq_len + 1 + ahead)
            if table is not None:
                group.tables[state.slot, :len(table)] = table
                return True
        need = state.seq_len + 1
        table = self.pool.extend(state.request_id, need)
        while table is None and self.cfg.preempt:
            victim = self._pick_victim(exclude_id=state.request_id)
            if victim is None:
                return False
            self._preempt(*victim)
            table = self.pool.extend(state.request_id, need)
        if table is None:
            return False
        group.tables[state.slot, :len(table)] = table
        return True

    # -- token bookkeeping -------------------------------------------------

    def _append_token(self, group: _PolicyGroup, state: RequestState,
                      token: int):
        now = time.perf_counter()
        if state.last_token_time:
            gap = now - state.last_token_time
            state.token_gaps_s.append(gap)
            self._tok_gaps.append(gap)
        state.last_token_time = now
        state.output.append(token)
        group.last_tok[state.slot] = token
        reason = ""
        if state.eos_id is not None and token == state.eos_id:
            reason = "eos"
        elif len(state.output) >= state.request.max_new_tokens:
            reason = "length"
        if reason:
            slot = state.slot  # retire() resets it; event wants the real one
            group.sched.retire(slot, reason, self.step, now=now)
            group.tables[slot] = SENTINEL
            self.pool.free(state.request_id)
            self._event("retire", state, slot, reason=reason)

    # -- launch / fetch / apply (async tick phases) --------------------------

    def _launch_prefill(self, group: _PolicyGroup) -> Optional[dict]:
        """Dispatch one prefill chunk for every row of ``group`` still
        ingesting its prompt (no host sync); rows reaching the last prompt
        token emit their first generated token at apply time. Decode rows
        are masked out (sentinel tables) so their K/V is untouched."""
        rows = group.prefill_rows
        if not rows:
            return None
        cfg = self.cfg
        chunk = cfg.prefill_chunk
        r = cfg.num_slots
        tokens = np.zeros((r, chunk), np.int32)
        tables = np.full_like(group.tables, SENTINEL)
        pos = np.zeros((r,), np.int32)
        last_idx = np.zeros((r,), np.int32)
        finishing: Set[int] = set()
        for slot, state in rows.items():
            prompt = state.request.prompt
            piece = prompt[state.next_pos:state.next_pos + chunk]
            tokens[slot, :len(piece)] = piece
            tables[slot] = group.tables[slot]
            pos[slot] = state.next_pos
            last_idx[slot] = len(piece) - 1
            if state.next_pos + len(piece) == len(prompt):
                finishing.add(slot)
            state.next_pos += len(piece)
        t0 = time.perf_counter()
        tok, self.kv = group.step_fn(
            self.params, self.kv, jnp.asarray(tokens), jnp.asarray(tables),
            jnp.asarray(pos), jnp.asarray(last_idx))
        return {"group": group, "kind": "prefill", "rows": rows,
                "finishing": finishing, "tok": tok, "t0": t0}

    def _launch_decode(self, group: _PolicyGroup,
                       stalled: Set[int]) -> Optional[dict]:
        """Dispatch one decode token for every generating row of ``group``
        (no host sync); prefill, stalled, and idle rows are masked out."""
        rows = {s: st for s, st in group.decode_rows.items()
                if st.request_id not in stalled}
        if not rows:
            return None
        r = self.cfg.num_slots
        tables = np.full_like(group.tables, SENTINEL)
        pos = np.zeros((r,), np.int32)
        for slot, state in rows.items():
            tables[slot] = group.tables[slot]
            pos[slot] = state.seq_len  # write position of the fed-back token
        t0 = time.perf_counter()
        tok, self.kv = group.step_fn(
            self.params, self.kv, jnp.asarray(group.last_tok[:, None]),
            jnp.asarray(tables), jnp.asarray(pos),
            jnp.zeros((r,), jnp.int32))
        return {"group": group, "kind": "decode", "rows": rows,
                "tok": tok, "t0": t0}

    def _launch_spec(self, group: _PolicyGroup,
                     stalled: Set[int]) -> Optional[dict]:
        """Speculative decode for ``group``'s generating rows: chain
        ``spec_k`` S=1 draft steps (draft-policy model, same pages — the
        drafted K/V is scratch the verify step overwrites in place), then
        launch the batched S=spec_k+1 verify under the group's own policy.
        All ``spec_k + 1`` dispatches go out without a host sync; the
        accept/reject fold happens at apply time from one fetched
        ``(greedy, n_acc)`` pair.

        Each row's acceptance is capped by its actual page coverage
        (``caps``): when the speculative ``extend`` failed, candidate
        positions past the mapped pages saw dropped writes/garbage reads,
        so only the in-coverage prefix — whose attention window is fully
        mapped — is trusted. Positions ``<= cap`` attend only mapped,
        exactly-written K/V, so the accepted tokens are exact."""
        rows = {s: st for s, st in group.decode_rows.items()
                if st.request_id not in stalled}
        if not rows:
            return None
        cfg = self.cfg
        r = cfg.num_slots
        tables = np.full_like(group.tables, SENTINEL)
        pos = np.zeros((r,), np.int32)
        caps: Dict[int, int] = {}
        for slot, state in rows.items():
            tables[slot] = group.tables[slot]
            pos[slot] = state.seq_len  # write offset of the candidate window
            cov = int((group.tables[slot] != SENTINEL).sum()) * cfg.block_size
            caps[slot] = max(0, cov - 1 - state.seq_len)
        t0 = time.perf_counter()
        jt = jnp.asarray(tables)
        kv = self.kv
        toks = [jnp.asarray(group.last_tok)]
        for j in range(cfg.spec_k):
            nxt, kv = self._draft_step(self.params, kv, toks[-1][:, None],
                                       jt, jnp.asarray(pos + j))
            toks.append(nxt)
        cand = jnp.stack(toks, axis=1)  # (R, spec_k+1) candidate window
        greedy, n_acc, self.kv = group.verify_fn(self.params, kv, cand, jt,
                                                 jnp.asarray(pos))
        return {"group": group, "kind": "spec", "rows": rows, "tok": greedy,
                "n_acc": n_acc, "caps": caps, "t0": t0}

    def _fetch(self, rec: dict):
        """Block on a launched step's token array — the only host wait in
        the loop; the blocked time is the tick's idle accounting."""
        if "np_tok" in rec:
            return
        t0 = time.perf_counter()
        rec["np_tok"] = np.asarray(rec["tok"])
        if "n_acc" in rec:
            rec["np_acc"] = np.asarray(rec["n_acc"])
        t1 = time.perf_counter()
        self._idle_s += t1 - t0
        rec["dt"] = t1 - rec["t0"]

    def _apply(self, rec: dict):
        """Fold a fetched step's tokens back into scheduler/pool state."""
        group, rows, tok = rec["group"], rec["rows"], rec["np_tok"]
        dt = rec["dt"]
        if rec["kind"] == "prefill":
            self._prefill_s += dt
            now = time.perf_counter()
            for slot, state in rows.items():
                state.prefill_s += dt
                if slot in rec["finishing"]:
                    state.first_token_time = now
                    self.pool.commit_prefix(state.request_id)
                    self._append_token(group, state, int(tok[slot]))
                if state.request_id in self.pool:
                    self.pool.advance(state.request_id, state.seq_len)
        elif rec["kind"] == "spec":
            self._step_times.append(dt)
            self.watchdog.observe(dt)
            k = self.cfg.spec_k
            rates = []
            self._spec_steps += 1
            for slot, state in list(rows.items()):
                greedy = tok[slot]
                raw = int(rec["np_acc"][slot])      # draft-quality signal
                n_acc = min(raw, rec["caps"][slot])  # coverage-capped
                emitted = 0
                for j in range(n_acc + 1):
                    self._append_token(group, state, int(greedy[j]))
                    emitted += 1
                    if state.slot < 0:  # retired (eos / length): exact
                        break           # decode would have stopped here too
                self._spec_row_steps += 1
                self._spec_drafted += k
                self._spec_accepted += emitted - 1
                self._spec_emitted += emitted
                state.spec_drafted += k
                state.spec_accepted += emitted - 1
                rates.append(min(raw, k) / k)
                if state.request_id in self.pool:
                    self.pool.advance(state.request_id, state.seq_len)
                    if self.cfg.preempt:
                        # roll the speculative reservation back: pages
                        # covering only rejected positions return to the
                        # pool; the partially-kept page's stale cells are
                        # overwritten by the next window
                        freed = self.pool.truncate(state.request_id,
                                                   state.seq_len)
                        if freed:
                            row = group.tables[state.slot]
                            mapped = int((row != SENTINEL).sum())
                            row[mapped - freed:] = SENTINEL
            self._update_spec_controller(group, rates)
        else:
            self._step_times.append(dt)
            self.watchdog.observe(dt)
            for slot, state in list(rows.items()):
                self._append_token(group, state, int(tok[slot]))
                if state.request_id in self.pool:
                    self.pool.advance(state.request_id, state.seq_len)

    def _update_spec_controller(self, group: _PolicyGroup,
                                rates: List[float]):
        """Dynamic-k controller: EWMA the verify acceptance rate and shut a
        group's speculation off (``spec_k -> 0``, plain decode) once the
        warmed-up average sinks below ``spec_min_accept`` — worst-case
        traffic pays a bounded number of wasted draft windows, then plain
        decode speed. Token identity never depends on the controller: a
        disabled group just takes the S=1 path."""
        if not rates:
            return
        rate = float(np.mean(rates))
        a = self._SPEC_EWMA_ALPHA
        group.spec_ewma = (rate if group.spec_ewma is None
                           else a * rate + (1 - a) * group.spec_ewma)
        group.spec_obs += 1
        if (group.spec_obs >= self._SPEC_WARMUP
                and group.spec_ewma < self.cfg.spec_min_accept):
            group.spec_on = False
            self._spec_disabled += 1
            self.events.append(dict(
                step=self.step, event="spec_off", request_id=-1, slot=-1,
                group=group.label, ewma=round(group.spec_ewma, 3)))

    # -- tick loop -----------------------------------------------------------

    def _stamp_arrivals(self, now: float):
        for group in self.groups.values():
            for waiting in group.sched.waiting:  # trace replay: stamp arrival
                if (waiting.arrival_time == 0.0
                        and waiting.request.arrival_step <= self.step):
                    waiting.arrival_time = now

    def _admit_all(self, allow_preempt: bool) -> bool:
        any_admitted = False
        for group in self.groups.values():
            admitted = group.sched.admit(
                self.step,
                can_admit=lambda st, g=group: self._try_reserve(
                    g, st, allow_preempt))
            if admitted:
                self._admit(group, admitted)
                any_admitted = True
        return any_admitted

    def _sample_util(self):
        active = sum(len(g.sched.active) for g in self.groups.values())
        if active:
            util = self.pool.utilization()["pool_util"]
            self._util_samples.append(util)
            self._util_peak = max(self._util_peak, util)

    def tick(self) -> bool:
        """One engine iteration, in phases:

        0. grow decode tables for this tick's writes (may preempt/swap);
        1. *launch* every group's prefill chunk + decode step — no host
           sync (``overlap=False``: fetch immediately, the sync baseline);
        2. overlapped host work while the device runs: arrival stamping,
           admission + page reservation (next tick's batch assembly),
           utilization sampling;
        3. blocking token fetch, then fold tokens into scheduler state;
        4. post-retirement admission (pages just freed; preemption/resume
           allowed here — nothing is in flight).

        Returns False when fully drained."""
        if not any(g.sched.has_work for g in self.groups.values()):
            return False
        stalled: Set[int] = set()
        for group in self.groups.values():
            # speculative rows want spec_k extra positions of coverage, but
            # only in preempt mode (on-demand growth + truncate rollback);
            # a whole-lifetime reservation already covers every position
            # acceptance can reach, so reserve mode never over-allocates
            ahead = (self.cfg.spec_k
                     if group.spec_on and self.cfg.preempt else 0)
            for _slot, state in list(group.decode_rows.items()):
                if state.request_id not in self.pool:
                    continue  # preempted as a victim earlier this phase
                if not self._ensure_blocks(group, state, ahead=ahead):
                    stalled.add(state.request_id)
        inflight = []
        for group in self.groups.values():
            rec = self._launch_prefill(group)
            if rec is not None:
                inflight.append(rec)
                if not self.cfg.overlap:
                    self._fetch(rec)
        for group in self.groups.values():
            rec = (self._launch_spec(group, stalled) if group.spec_on
                   else self._launch_decode(group, stalled))
            if rec is not None:
                inflight.append(rec)
                if not self.cfg.overlap:
                    self._fetch(rec)
        now = time.perf_counter()
        self._stamp_arrivals(now)
        admitted = self._admit_all(allow_preempt=False)
        self._sample_util()
        for rec in inflight:
            # fetch+apply interleaved: applying an earlier record's host
            # bookkeeping (token append, prefix commit, retirement) runs
            # while later records are still computing on the device
            self._fetch(rec)
            self._apply(rec)
        admitted |= self._admit_all(allow_preempt=self.cfg.preempt)
        self._peak_active = max(
            self._peak_active,
            sum(len(g.sched.active) for g in self.groups.values()))
        arrived_waiting = any(
            st.request.arrival_step <= self.step
            for g in self.groups.values() for st in g.sched.waiting)
        if inflight or admitted or not arrived_waiting:
            self._stuck_ticks = 0
        else:
            self._stuck_ticks += 1
            if self._stuck_ticks >= self._STUCK_TICKS:
                raise RuntimeError(
                    f"serving livelock: {self._stuck_ticks} ticks with "
                    "waiting work but no progress — the KV pool and swap "
                    "buffer together cannot host any runnable request "
                    "(undersized swap_blocks? see daism-lint SRV008)")
        self.step += 1
        return any(g.sched.has_work for g in self.groups.values())

    # -- driver ------------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve ``requests`` to completion and report. Single-use: the
        report aggregates everything the engine has done, so reuse would
        fold the previous run's accounting into the next report — build a
        fresh engine (or drive tick()/submit() yourself) instead."""
        if self._step_times or any(g.sched.finished
                                   for g in self.groups.values()):
            raise RuntimeError(
                "ServeEngine.run() is single-use; build a fresh engine")
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while self.tick():
            pass
        wall = time.perf_counter() - t0
        done = [s for g in self.groups.values() for s in g.sched.finished]
        done.sort(key=lambda s: s.request_id)
        generated = sum(len(s.output) for s in done)
        decode_s = float(sum(self._step_times))
        # prefill produces 1 token/request; the rest ride decode steps
        decode_tokens = generated - len(done)
        gaps_ms = [g * 1e3 for g in self._tok_gaps]
        return ServeReport(
            completed=done,
            wall_s=wall,
            prefill_s=self._prefill_s,
            decode_s=decode_s,
            decode_steps=len(self._step_times),
            generated_tokens=generated,
            tokens_per_s=decode_tokens / decode_s if decode_s else 0.0,
            ttft_p50_ms=_pct([s.ttft_s * 1e3 for s in done], 50),
            ttft_p95_ms=_pct([s.ttft_s * 1e3 for s in done], 95),
            ttft_p99_ms=_pct([s.ttft_s * 1e3 for s in done], 99),
            latency_p50_ms=_pct([s.latency_s * 1e3 for s in done], 50),
            latency_p95_ms=_pct([s.latency_s * 1e3 for s in done], 95),
            latency_p99_ms=_pct([s.latency_s * 1e3 for s in done], 99),
            tok_lat_p50_ms=_pct(gaps_ms, 50),
            tok_lat_p95_ms=_pct(gaps_ms, 95),
            tok_lat_p99_ms=_pct(gaps_ms, 99),
            step_p50_ms=_pct([t * 1e3 for t in self._step_times], 50),
            step_p99_ms=_pct([t * 1e3 for t in self._step_times], 99),
            joined_mid_stream=sum(s.joined_running_batch for s in done),
            straggler_steps=self.watchdog.stragglers,
            ticks=self.step,
            host_idle_s=self._idle_s,
            host_idle_frac=self._idle_s / wall if wall else 0.0,
            kv_util_mean=(float(np.mean(self._util_samples))
                          if self._util_samples else 0.0),
            kv_util_peak=self._util_peak,
            peak_active_requests=self._peak_active,
            prefix_hits=self.pool.prefix_hits,
            preemptions=self._preemptions,
            resumes=self._resumes,
            policy_groups=len(self.groups),
            shards=self.shards,
            events=self.events,
            spec_steps=self._spec_steps,
            spec_drafted=self._spec_drafted,
            spec_accepted=self._spec_accepted,
            spec_accept_rate=(self._spec_accepted / self._spec_drafted
                              if self._spec_drafted else 0.0),
            spec_tokens_per_step=(self._spec_emitted / self._spec_row_steps
                                  if self._spec_row_steps else 0.0),
            spec_disabled_groups=self._spec_disabled,
        )
