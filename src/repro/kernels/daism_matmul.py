"""Pallas TPU kernel for the DAISM approximate matmul (bfloat16).

This is the paper's compute hot spot mapped to the TPU memory hierarchy
(DESIGN.md §2): the SRAM wired-OR read becomes a bit-parallel shift/OR chain
on int32 VPU lanes; the pre-computed PC2/PC3 head lines become constant-
folded selected adds; truncation is a free column mask (carry-free).

Tiling: grid (M/bm, N/bn, K/bk) with K innermost so the f32 accumulator tile
stays resident in VMEM across the K sweep (revisiting semantics). Working set
per step:

    a tile (bm, bk) bf16 + w tile (bk, bn) bf16         (streamed from HBM)
    decomposed int32 planes + (bm, bk, bn) f32 products (VMEM scratch)
    out tile (bm, bn) f32                                (resident)

Defaults (bm=8, bk=128, bn=128) keep the peak VMEM footprint
~ 8*128*128*4B * ~3 live temporaries ≈ 1.5 MiB — comfortable within a
16 MiB VMEM budget, with MXU-aligned (multiple-of-128) N/K tile edges for
the exact-baseline comparison kernel.

Validated in interpret mode on CPU against kernels/ref.py (bit-exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.config import DaismConfig, Variant

_BIAS = 127


def _decompose_bf16_i32(x):
    bits = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.int32)
    sign = bits >> 15
    exp = (bits >> 7) & 0xFF
    man = jnp.where(exp > 0, (bits & 0x7F) | 0x80, 0)
    return sign, exp, man


def _bit(b, i):
    return (b >> i) & 1


def _approx_mantissa_product(mw, mx, variant: Variant):
    """8-bit mantissa approximate product (int32), float mode (MSB set)."""
    base = variant.base
    if base is Variant.EXACT:
        out = mw * mx
    elif base is Variant.FLA:
        out = jnp.zeros_like(mw)
        for i in range(8):
            out = out | jnp.where(_bit(mx, i) == 1, mw << i, 0)
    elif base is Variant.HLA:
        even = jnp.zeros_like(mw)
        odd = jnp.zeros_like(mw)
        for i in range(0, 8, 2):
            even = even | jnp.where(_bit(mx, i) == 1, mw << i, 0)
        for i in range(1, 8, 2):
            odd = odd | jnp.where(_bit(mx, i) == 1, mw << i, 0)
        out = even + odd
    elif base in (Variant.PC2, Variant.PC3):
        k = 2 if base is Variant.PC2 else 3
        w = _bit(mx, 7) | 1  # float mode: A always active
        for j in range(1, k):
            w = 2 * w + _bit(mx, 7 - j)
        out = (mw * w) << (8 - k)
        for i in range(0, 8 - k):
            out = out | jnp.where(_bit(mx, i) == 1, mw << i, 0)
    else:  # pragma: no cover
        raise ValueError(variant)
    if variant.truncated:
        out = out & (0xFF << 8)
    return out


def _product_block_f32(a_tile, w_tile, variant: Variant):
    """(bm, bk) x (bk, bn) bf16 -> (bm, bk, bn) f32 approximate products."""
    sx, ex, mx = _decompose_bf16_i32(a_tile)   # input = multiplier
    sw, ew, mw = _decompose_bf16_i32(w_tile)   # weight = multiplicand
    mx3, ex3, sx3 = mx[:, :, None], ex[:, :, None], sx[:, :, None]
    mw3, ew3, sw3 = mw[None, :, :], ew[None, :, :], sw[None, :, :]

    prod = _approx_mantissa_product(mw3, mx3, variant)
    top = (prod >> 15) & 1
    man = jnp.where(top == 1, prod >> 8, prod >> 7) & 0xFF

    sign = sx3 ^ sw3
    exp = ex3 + ew3 - _BIAS + top
    zero = (mx3 == 0) | (mw3 == 0)
    exp = jnp.where(zero, 0, exp)
    man = jnp.where(zero, 0, man)
    # Compose f32 directly from integer fields (subnormal-flush, saturate).
    is_zero = (man == 0) | (exp <= 0)
    is_inf = exp >= 255
    bits = (
        (sign.astype(jnp.uint32) << 31)
        | (jnp.clip(exp, 0, 254).astype(jnp.uint32) << 23)
        | ((man << 16) & 0x7FFFFF).astype(jnp.uint32)
    )
    bits = jnp.where(is_zero, sign.astype(jnp.uint32) << 31, bits)
    bits = jnp.where(is_inf & ~is_zero,
                     (sign.astype(jnp.uint32) << 31) | jnp.uint32(0x7F800000), bits)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _kernel(a_ref, w_ref, o_ref, *, variant: Variant, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_tile = a_ref[...]
    w_tile = w_ref[...]
    if variant is Variant.EXACT:
        # Exact-baseline kernel: straight MXU matmul on the same tiling.
        o_ref[...] += jnp.dot(
            a_tile.astype(jnp.float32), w_tile.astype(jnp.float32),
            preferred_element_type=jnp.float32)
    else:
        prod = _product_block_f32(a_tile, w_tile, variant)
        o_ref[...] += prod.sum(axis=1)


def daism_matmul_kernel(
    a: jnp.ndarray,
    w: jnp.ndarray,
    *,
    variant: Variant = Variant.PC3_TR,
    block_m: int = 8,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """(M, K) @ (K, N) -> (M, N) f32 via the DAISM Pallas kernel.

    Requires M % block_m == K % block_k == N % block_n == 0 (the ops.py
    wrapper pads). bf16 inputs only (f32 uses the dual-plane jnp path).
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, k, n), (block_m, block_k, block_n))
    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_kernel, variant=Variant(variant), k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, w)
