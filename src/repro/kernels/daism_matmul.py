"""Pallas TPU kernel for the DAISM approximate matmul (bfloat16).

This is the paper's compute hot spot mapped to the TPU memory hierarchy
(DESIGN.md §2): the SRAM wired-OR read becomes a bit-parallel shift/OR chain
on int32 VPU lanes; the pre-computed PC2/PC3 head lines become constant-
folded selected adds; truncation is a free column mask (carry-free).

Tiling: grid (M/bm, N/bn, K/bk) with K innermost so the f32 accumulator tile
stays resident in VMEM across the K sweep (revisiting semantics). The inner
tile contraction is the *fused* shift-plane sweep from
:mod:`~repro.kernels.approx_product`: K is consumed in
:data:`~repro.kernels.approx_product.K_FUSE`-wide sub-chunks whose products
fold straight into the (bm, bn) accumulator, so the (bm, bk, bn) product
tensor of the original kernel never materializes. Working set per step:

    a tile (bm, bk) bf16 + w tile (bk, bn) bf16          (streamed from HBM)
    decomposed int32 fields + (bm, K_FUSE, bn) slabs     (VMEM, K-independent)
    out tile (bm, bn) f32                                 (resident)

Defaults (bm=32, bk=128, bn=128): the fusion removed the bm*bk*bn term, so
the M tile rises 8 -> 32 (4x fewer grid steps) while peak VMEM stays
~ 3 * 32*8*128 * 4 B of live slab temporaries + tiles ≈ 0.5 MiB —
comfortable within a 16 MiB VMEM budget, with MXU-aligned
(multiple-of-128) N/K tile edges for the exact-baseline comparison kernel.

Validated in interpret mode on CPU against kernels/ref.py (bit-exact
per-element products; f32 accumulation-order tolerance).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.config import DaismConfig, Variant

from .approx_product import approx_matmul_tile


def _kernel(a_ref, w_ref, o_ref, *, variant: Variant):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_tile = a_ref[...]
    w_tile = w_ref[...]
    if variant is Variant.EXACT:
        # Exact-baseline kernel: straight MXU matmul on the same tiling.
        o_ref[...] += jnp.dot(
            a_tile.astype(jnp.float32), w_tile.astype(jnp.float32),
            preferred_element_type=jnp.float32)
    else:
        o_ref[...] += approx_matmul_tile(a_tile, w_tile, variant)


def daism_matmul_kernel(
    a: jnp.ndarray,
    w: jnp.ndarray,
    *,
    variant: Variant = Variant.PC3_TR,
    block_m: int = 32,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(M, K) @ (K, N) -> (M, N) f32 via the DAISM Pallas kernel.

    Requires M % block_m == K % block_k == N % block_n == 0 (the ops.py
    wrapper pads). bf16 inputs only (f32 uses the dual-plane jnp path).
    ``interpret=None`` resolves through
    :func:`repro.policy.dispatch.auto_interpret` (explicit setting wins,
    else interpret on CPU, compiled on TPU) so direct callers never silently
    benchmark interpret mode on hardware.
    """
    from repro.policy.dispatch import auto_interpret

    m, k = a.shape
    k2, n = w.shape
    assert k == k2
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, k, n), (block_m, block_k, block_n))
    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_kernel, variant=Variant(variant))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=auto_interpret(interpret),
    )(a, w)
