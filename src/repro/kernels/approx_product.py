"""Shared DAISM approximate-product primitives for the Pallas kernels.

The bf16 decomposition, the Table-1 approximate mantissa product (the SRAM
wired-OR read mapped to shift/OR chains on int32 VPU lanes), and the f32
re-composition live here so both the GEMM kernel (daism_matmul.py) and the
fused flash-attention kernel (flash_attention.py) share one implementation —
both must stay bit-exact against kernels/ref.py.

:func:`approx_matmul_tile` is the fused tile contraction: instead of
materializing the full (bm, bk, bn) product tensor and reducing afterwards,
it sweeps K in :data:`K_FUSE`-wide sub-chunks, runs the shift-plane product
on each (bm, K_FUSE, bn) slab, and folds the slab straight into the (bm, bn)
f32 accumulator. Peak live intermediate drops from O(bm*bk*bn) to
O(bm*K_FUSE*bn), which is what lets the GEMM kernel raise its M tile and the
attention kernel keep scores + products VMEM-resident.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import Variant

_BIAS = 127

# K-dim sub-chunk width of the fused plane sweep. 8 keeps the live
# (bm, K_FUSE, bn) slabs at VPU-sublane granularity: with bm = bn = 128 the
# ~3 live int32/f32 temporaries total ~1.5 MiB, independent of block_k.
K_FUSE = 8


def decompose_bf16_i32(x):
    """bf16 -> (sign, exponent, mantissa-with-hidden-1) int32 fields."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.int32)
    sign = bits >> 15
    exp = (bits >> 7) & 0xFF
    man = jnp.where(exp > 0, (bits & 0x7F) | 0x80, 0)
    return sign, exp, man


def _bit(b, i):
    return (b >> i) & 1


def approx_mantissa_product(mw, mx, variant: Variant):
    """8-bit mantissa approximate product (int32), float mode (MSB set)."""
    base = variant.base
    if base is Variant.EXACT:
        out = mw * mx
    elif base is Variant.FLA:
        out = jnp.zeros_like(mw)
        for i in range(8):
            out = out | jnp.where(_bit(mx, i) == 1, mw << i, 0)
    elif base is Variant.HLA:
        even = jnp.zeros_like(mw)
        odd = jnp.zeros_like(mw)
        for i in range(0, 8, 2):
            even = even | jnp.where(_bit(mx, i) == 1, mw << i, 0)
        for i in range(1, 8, 2):
            odd = odd | jnp.where(_bit(mx, i) == 1, mw << i, 0)
        out = even + odd
    elif base in (Variant.PC2, Variant.PC3):
        k = 2 if base is Variant.PC2 else 3
        w = _bit(mx, 7) | 1  # float mode: A always active
        for j in range(1, k):
            w = 2 * w + _bit(mx, 7 - j)
        out = (mw * w) << (8 - k)
        for i in range(0, 8 - k):
            out = out | jnp.where(_bit(mx, i) == 1, mw << i, 0)
    else:  # pragma: no cover
        raise ValueError(variant)
    if variant.truncated:
        out = out & (0xFF << 8)
    return out


def product_block_f32(a_tile, w_tile, variant: Variant):
    """(bm, bk) x (bk, bn) bf16 -> (bm, bk, bn) f32 approximate products."""
    sx, ex, mx = decompose_bf16_i32(a_tile)   # input = multiplier
    sw, ew, mw = decompose_bf16_i32(w_tile)   # weight = multiplicand
    return compose_products_f32(
        (sx[:, :, None], ex[:, :, None], mx[:, :, None]),
        (sw[None, :, :], ew[None, :, :], mw[None, :, :]), variant)


def compose_products_f32(x_fields, w_fields, variant: Variant):
    """Broadcast (sign, exp, man) field triples -> f32 approximate products.

    The mantissa product uses the variant's shift-plane chain; normalization,
    exponent add, subnormal-flush, and saturation compose the f32 directly
    from integer fields (bit-exact vs core.floatmul / kernels/ref.py).
    """
    sx3, ex3, mx3 = x_fields
    sw3, ew3, mw3 = w_fields
    prod = approx_mantissa_product(mw3, mx3, variant)
    top = (prod >> 15) & 1
    man = jnp.where(top == 1, prod >> 8, prod >> 7) & 0xFF

    sign = sx3 ^ sw3
    exp = ex3 + ew3 - _BIAS + top
    zero = (mx3 == 0) | (mw3 == 0)
    exp = jnp.where(zero, 0, exp)
    man = jnp.where(zero, 0, man)
    is_zero = (man == 0) | (exp <= 0)
    is_inf = exp >= 255
    bits = (
        (sign.astype(jnp.uint32) << 31)
        | (jnp.clip(exp, 0, 254).astype(jnp.uint32) << 23)
        | ((man << 16) & 0x7FFFFF).astype(jnp.uint32)
    )
    bits = jnp.where(is_zero, sign.astype(jnp.uint32) << 31, bits)
    bits = jnp.where(is_inf & ~is_zero,
                     (sign.astype(jnp.uint32) << 31) | jnp.uint32(0x7F800000),
                     bits)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def approx_matmul_tile(a_tile, w_tile, variant: Variant, *,
                       k_fuse: int = K_FUSE) -> jnp.ndarray:
    """(bm, bk) @ (bk, bn) bf16 -> (bm, bn) f32, fused shift-plane sweep.

    The K reduction is folded into the plane loop: each ``k_fuse``-wide
    sub-chunk's products are composed and summed into the accumulator before
    the next sub-chunk's planes are formed, so no (bm, bk, bn) tensor ever
    exists. Operand decomposition is hoisted out of the sweep (amortized
    over bn for ``a`` and over bm for ``w``).
    """
    bm, bk = a_tile.shape
    bn = w_tile.shape[1]
    sx, ex, mx = decompose_bf16_i32(a_tile)   # (bm, bk)
    sw, ew, mw = decompose_bf16_i32(w_tile)   # (bk, bn)
    acc = jnp.zeros((bm, bn), jnp.float32)
    for lo in range(0, bk, k_fuse):
        hi = min(lo + k_fuse, bk)
        slab = compose_products_f32(
            (sx[:, lo:hi, None], ex[:, lo:hi, None], mx[:, lo:hi, None]),
            (sw[None, lo:hi, :], ew[None, lo:hi, :], mw[None, lo:hi, :]),
            variant)
        acc = acc + slab.sum(axis=1)
    return acc
