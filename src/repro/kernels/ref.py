"""Pure-jnp oracles for the Pallas kernels.

``daism_matmul_ref`` is the normative semantics: exact f32 accumulation of
per-element approximate products from ``core.floatmul`` (which is itself
validated against numpy bit-level oracles in tests/). Kernel outputs must be
bit-exact against this for every variant/shape/dtype swept in tests.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import Variant
from repro.core.floatmul import approx_mul_to_f32


def daism_matmul_ref(a: jnp.ndarray, w: jnp.ndarray, variant: Variant) -> jnp.ndarray:
    """(M, K) @ (K, N) -> (M, N) f32. Materializes (M, K, N); test-scale only."""
    variant = Variant(variant)
    if variant is Variant.EXACT:
        return jnp.matmul(a.astype(jnp.float32), w.astype(jnp.float32))
    prod = approx_mul_to_f32(a[:, :, None], w[None, :, :], variant)
    return prod.sum(axis=1)
