"""Pallas TPU kernels for DAISM's compute hot spots.

daism_matmul.py    - approximate GEMM: pl.pallas_call + BlockSpec VMEM tiling
flash_attention.py - fused online-softmax attention, exact or DAISM-approx
approx_product.py  - shared bf16 decompose / shift-plane product primitives
ops.py             - jit'd wrappers (padding, dispatch, interpret auto-detect)
ref.py             - pure-jnp oracles the kernels are validated against
"""
from .approx_product import (approx_matmul_tile, approx_mantissa_product,
                             compose_products_f32, decompose_bf16_i32)
from .flash_attention import flash_attention, flash_attention_bhsd
from .ops import daism_matmul_pallas
from .ref import daism_matmul_ref

__all__ = [
    "approx_matmul_tile",
    "approx_mantissa_product",
    "compose_products_f32",
    "daism_matmul_pallas",
    "daism_matmul_ref",
    "decompose_bf16_i32",
    "flash_attention",
    "flash_attention_bhsd",
]
