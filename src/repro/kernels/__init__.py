"""Pallas TPU kernels for DAISM's compute hot spot (the approximate GEMM).

daism_matmul.py - pl.pallas_call + BlockSpec VMEM tiling (bf16)
ops.py          - jit'd wrappers (padding, dispatch, interpret auto-detect)
ref.py          - pure-jnp oracles the kernels are validated against
"""
from .ops import daism_matmul_pallas
from .ref import daism_matmul_ref

__all__ = ["daism_matmul_pallas", "daism_matmul_ref"]
