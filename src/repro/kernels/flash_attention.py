"""Flash attention Pallas kernel (beyond-paper §Perf optimization).

The dry-run roofline shows every attention-bearing cell is MEMORY-bound, and
the dominant traffic is the materialized (B, H, Sq, Skv-chunk) score/weight
tensors of the jnp online-softmax path (EXPERIMENTS.md §Perf: tinyllama
train_4k memory term 5.81 s vs 0.22 s compute). This kernel keeps scores in
VMEM: HBM traffic collapses to q+k+v+o (+small m/l), removing the score
tensors entirely.

Tiling: grid (B*H, Sq/bq, Skv/bk), KV innermost with the (m, l, acc)
accumulator resident across the KV sweep. Causal masking by absolute
position; fully-masked tiles still execute (structural simplicity; the
index-map skip is a further 2x — noted in §Perf).

Validated in interpret mode against models.layers.attend (the production
online-softmax) and a naive softmax oracle in tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, kv_steps: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T)                               # (bq, bk) in VMEM only

    if causal:
        q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        k_pos = kv_i * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + p.sum(-1)
    acc_new = acc_prev * corr[:, None] + jnp.dot(p, v)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(kv_i == kv_steps - 1)
    def _finalize():
        o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True
                    ) -> jnp.ndarray:
    """q: (BH, Sq, D), k/v: (BH, Skv, D) -> (BH, Sq, D).

    Sq % block_q == Skv % block_k == 0 (wrapper pads). Scores never touch
    HBM: per-step working set = q,k,v tiles + (bq, bk) scores + (bq, D) acc
    ~= (3*128*D + 128*128 + 128*D)*4 B — < 1 MiB at D=128, VMEM-resident.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (bh, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(d), causal=causal, bq=block_q,
        bk=block_k, kv_steps=grid[2])
    out, _, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((block_q,), lambda b, i, j: (i,)),
            pl.BlockSpec((block_q,), lambda b, i, j: (i,)),
            pl.BlockSpec((block_q, d), lambda b, i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((sq,), jnp.float32),       # m scratch
            jax.ShapeDtypeStruct((sq,), jnp.float32),       # l scratch
            jax.ShapeDtypeStruct((sq, d), jnp.float32),     # acc scratch
        ],
        interpret=interpret,
    )(q, k, v)
    return out


def flash_attention_bhsd(q, k, v, *, causal=True, interpret=True,
                         block_q=128, block_k=128):
    """(B, S, H, D) layout wrapper with GQA head repeat + padding."""
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    pq = (-sq) % block_q
    pk = (-skv) % block_k
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, pq), (0, 0)))
    if pk:  # padded keys land at positions > any query: masked by causal;
        # for non-causal, pad with -inf via explicit mask is needed — the
        # wrapper only supports causal padding (asserted).
        assert causal, "non-causal padding unsupported in wrapper"
        kt = jnp.pad(kt, ((0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pk), (0, 0)))
    out = flash_attention(qt, kt, vt, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
