"""Flash attention Pallas kernel with fused DAISM approximate products.

The dry-run roofline shows every attention-bearing cell is MEMORY-bound, and
the dominant traffic is the materialized (B, H, Sq, Skv-chunk) score/weight
tensors of the jnp online-softmax path (EXPERIMENTS.md §Perf: tinyllama
train_4k memory term 5.81 s vs 0.22 s compute). This kernel keeps scores in
VMEM: HBM traffic collapses to q+k+v+o, removing the score tensors entirely.
The (m, l, acc) online-softmax accumulators are VMEM *scratch*
(``scratch_shapes``) — they never touch HBM and carry no cross-batch
aliasing hazard (an earlier revision emitted them as outputs indexed only by
the query tile, silently shared across the batch grid axis).

DAISM fusion (the paper's approximate multiplier inside attention): with
``variant`` set, the QK and PV contractions run the shared shift-plane
approximate product (:mod:`~repro.kernels.approx_product`) instead of the
MXU dot — scores *and* approximate products stay VMEM-resident, which is
the only regime where the in-SRAM multiplier's data-movement win survives
(PIM-DRAM: in-memory GEMM loses if the dataflow materializes
intermediates). P is cast to bf16 before the PV product (the multiplier is
an 8-bit-mantissa device); products are bit-exact vs ``kernels/ref.py``.

Tiling: grid (B*H, Sq/bq, Skv/bk), KV innermost with the (m, l, acc)
scratch resident across the KV sweep. Causal masking by absolute position;
KV padding is masked explicitly from the true key length, so non-causal
(cross/encoder) attention works for ragged sequence lengths. Fully-masked
tiles still execute (structural simplicity; the index-map skip is a further
2x — noted in §Perf).

Validated in interpret mode against models.layers.attend (the production
online-softmax), a naive softmax oracle, and ``daism_matmul_ref`` composed
with a naive softmax in tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.config import Variant

from .approx_product import approx_matmul_tile

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, kv_steps: int,
            kv_len: int, variant: Optional[Variant]):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                      # (bq, d)
    k = k_ref[0]                                      # (bk, d)
    v = v_ref[0]
    if variant is None:
        s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T)
    else:                                             # fused DAISM product
        s = approx_matmul_tile(q, k.T, variant)       # (bq, bk) in VMEM only
    s = s * scale

    mask = None
    if causal:
        q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        k_pos = kv_i * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 1)
        mask = k_pos <= q_pos
    if kv_len < kv_steps * bk:  # ragged KV: mask padded keys explicitly
        k_pos = kv_i * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 1)
        valid = k_pos < kv_len
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        s = jnp.where(mask, s, _NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    if mask is not None:
        # exp(-1e30 - m) underflows to 0 once any real key has been seen,
        # but a tile where *every* key so far is masked has m == -1e30 and
        # p == 1; zero masked lanes explicitly so such rows stay empty.
        p = jnp.where(mask, p, 0.0)
    l_new = l_prev * corr + p.sum(-1)
    if variant is None:
        pv = jnp.dot(p, v.astype(jnp.float32))
    else:
        pv = approx_matmul_tile(p.astype(jnp.bfloat16), v, variant)
    acc_new = acc_prev * corr[:, None] + pv
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(kv_i == kv_steps - 1)
    def _finalize():
        o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, kv_len: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    variant: Optional[Variant] = None,
                    interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """q: (BH, Sq, D), k/v: (BH, Skv, D) -> (BH, Sq, D).

    Sq % block_q == Skv % block_k == 0 (wrapper pads); ``kv_len`` is the
    true (pre-padding) key length — keys at positions >= kv_len are masked
    out, so non-causal attention is correct for ragged lengths. Scores and
    the online-softmax state never touch HBM: per-step working set = q,k,v
    tiles + (bq, bk) scores + (bq, D) scratch acc — < 1 MiB at D=128.
    ``variant`` switches the QK/PV contractions to the DAISM approximate
    product (bf16 operands only). ``interpret=None`` resolves through
    :func:`repro.policy.dispatch.auto_interpret`.
    """
    from repro.policy.dispatch import auto_interpret

    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_k == 0
    if variant is not None:
        variant = Variant(variant)
        if variant is Variant.EXACT:
            variant = None
        elif q.dtype != jnp.bfloat16:
            raise ValueError(
                "flash attention with a DAISM variant is bfloat16-only "
                f"(got {jnp.dtype(q.dtype).name}); run the site exact or "
                "switch the compute dtype")
    kv_len = kv_len or skv
    grid = (bh, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(d), causal=causal, bq=block_q,
        bk=block_k, kv_steps=grid[2], kv_len=kv_len, variant=variant)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),           # m
            pltpu.VMEM((block_q,), jnp.float32),           # l
            pltpu.VMEM((block_q, d), jnp.float32),         # acc
        ],
        interpret=auto_interpret(interpret),
    )(q, k, v)


def flash_attention_bhsd(q, k, v, *, causal=True,
                         variant: Optional[Variant] = None,
                         interpret: Optional[bool] = None,
                         block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """(B, S, H, D) layout wrapper with GQA head repeat + padding."""
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    pq = (-sq) % block_q
    pk = (-skv) % block_k
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, pq), (0, 0)))
    if pk:  # padded keys are masked inside the kernel via kv_len
        kt = jnp.pad(kt, ((0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pk), (0, 0)))
    out = flash_attention(qt, kt, vt, causal=causal, kv_len=skv,
                          block_q=block_q, block_k=block_k, variant=variant,
                          interpret=interpret)
    return out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
