"""jit'd public wrappers around the Pallas kernels (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitops import round_up as _round_up
from repro.core.config import DaismConfig
from repro.policy.dispatch import auto_interpret as _auto_interpret

from .daism_matmul import daism_matmul_kernel


@functools.partial(jax.jit, static_argnums=(2,))
def daism_matmul_pallas(a: jnp.ndarray, w: jnp.ndarray, cfg: DaismConfig) -> jnp.ndarray:
    """(M, K) @ (K, N) -> (M, N) f32 with automatic pad-to-tile.

    Zero padding is semantics-preserving: approx(0 * w) == 0 contributes
    nothing to the exact accumulation.
    """
    if a.dtype != jnp.bfloat16 or w.dtype != jnp.bfloat16:
        raise ValueError("Pallas DAISM kernel is bfloat16-only; f32 uses the "
                         "dual-plane jnp backend")
    m, k = a.shape
    _, n = w.shape
    bm, bk, bn = cfg.block_m, cfg.block_k, cfg.block_n
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    w_p = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    out = daism_matmul_kernel(
        a_p, w_p,
        variant=cfg.variant,
        block_m=bm, block_n=bn, block_k=bk,
        interpret=_auto_interpret(cfg),
    )
    return out[:m, :n]
