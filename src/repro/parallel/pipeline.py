"""GPipe-style pipeline parallelism over a ``stage`` mesh axis (optional
feature — DESIGN.md §5; default production meshes use data x model, but at
>100B scale a stage axis bounds per-device weight residency where FSDP
gathers become the bottleneck, e.g. nemotron train at 698 GB/device).

Mechanics: the layer stack (L, ...) is sharded onto S stages (L/S layers
each) via shard_map; activations flow stage-to-stage with
``lax.ppermute`` over M microbatches in the classic (M + S - 1)-step
schedule (bubble fraction (S-1)/(M+S-1)). Forward-differentiable: ppermute
transposes to the reverse permutation, so jax.grad works through the whole
pipeline (GPipe's recompute-per-stage corresponds to remat='full' on the
layer body).

Numerical equivalence with the sequential scan is asserted in
tests/test_pipeline.py on a 4-stage mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(layer_fn: Callable, stacked_params, x: jnp.ndarray,
                   mesh: Mesh, *, n_microbatches: int,
                   stage_axis: str = "stage") -> jnp.ndarray:
    """Run ``x`` through L stacked layers split across pipeline stages.

    layer_fn(params_slice, h) -> h applies ONE layer. stacked_params leaves
    have leading dim L with L % n_stages == 0; x: (B, ...) with
    B % n_microbatches == 0.
    """
    n_stages = mesh.shape[stage_axis]
    lead = jax.tree.leaves(stacked_params)[0].shape[0]
    assert lead % n_stages == 0, (lead, n_stages)
    assert x.shape[0] % n_microbatches == 0

    def stage_body(p_loc, x_full):
        r = lax.axis_index(stage_axis)
        s = n_stages
        m = n_microbatches
        mbs = x_full.reshape(m, x_full.shape[0] // m, *x_full.shape[1:])

        def local_layers(h):
            def body(h, p):
                return layer_fn(p, h), None
            h, _ = lax.scan(body, h, p_loc)
            return h

        perm = [(i, (i + 1) % s) for i in range(s)]
        carry = jnp.zeros_like(mbs[0])
        outputs = jnp.zeros_like(mbs)

        def step(state, t):
            carry, outputs = state
            inp = jnp.where(r == 0, mbs[jnp.clip(t, 0, m - 1)], carry)
            out = local_layers(inp)
            nxt = lax.ppermute(out, stage_axis, perm)
            idx = t - (s - 1)
            ok = (r == s - 1) & (idx >= 0) & (idx < m)
            written = outputs.at[jnp.clip(idx, 0, m - 1)].set(out)
            outputs = jnp.where(ok, written, outputs)
            return (nxt, outputs), None

        (carry, outputs), _ = lax.scan(step, (carry, outputs),
                                       jnp.arange(m + s - 1))
        # broadcast the last stage's collected outputs to every stage
        outputs = lax.psum(jnp.where(r == s - 1, outputs, 0), stage_axis)
        return outputs.reshape(x_full.shape)

    in_specs = (jax.tree.map(lambda _: P(stage_axis), stacked_params), P())
    return shard_map(stage_body, mesh=mesh, in_specs=in_specs,
                     out_specs=P(), check_vma=False)(stacked_params, x)
