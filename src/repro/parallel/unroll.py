"""Ambient per-category scan-unroll control (roofline probe machinery).

XLA's HLO cost analysis counts a while-loop body once regardless of trip
count. To recover true per-device bytes/collective traffic from the compiled
artifact, the dry-run compiles PROBE variants of each cell with one scan
category unrolled by k: the cost delta equals (k-1) x (sum of that
category's loop bodies), from which the true trip-weighted total is
reconstructed (EXPERIMENTS.md §Roofline: methodology). Categories:

  layers — the stacked-parameter layer scans
  attn   — the online-softmax KV-chunk scans
  time   — SSM/xLSTM per-timestep recurrence scans

Default is 1 everywhere (production graphs are untouched).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict

_tls = threading.local()

_DEFAULT = {"layers": 1, "attn": 1, "time": 1}


def unroll_for(category: str) -> int:
    cfg = getattr(_tls, "unroll", None)
    if cfg is None:
        return 1
    return cfg.get(category, 1)


@contextlib.contextmanager
def use_unroll(**categories: int):
    prev = getattr(_tls, "unroll", None)
    cfg = dict(_DEFAULT)
    if prev:
        cfg.update(prev)
    cfg.update(categories)
    _tls.unroll = cfg
    try:
        yield
    finally:
        _tls.unroll = prev
