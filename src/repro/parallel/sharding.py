"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Parameters and activations are annotated with *logical* axis names; a
:class:`Sharder` maps them onto mesh axes with automatic divisibility
fallback (an axis that does not divide the dimension is dropped rather than
erroring — e.g. 4 KV heads on a 16-way model axis degrade to replication,
which is exactly the production behavior we want to surface in the roofline,
not hide behind a crash).

The active sharder is ambient (context manager) so model code can sprinkle
``constrain(x, ("act_batch", "act_seq", "act_embed"))`` without plumbing a
mesh through every call — outside a mesh context it is a no-op, which keeps
single-device smoke tests untouched.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRule = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

def base_rules(multi_pod: bool, *, seq_sharded_cache: bool = False,
               sp_activations: bool = False,
               serve: bool = False) -> Dict[str, AxisRule]:
    """Default production rules.

    Weights: 2-D sharded — 'fsdp'-tagged dims over the data(+pod) axes
    (ZeRO-3), 'model'-tagged dims over the tensor axis.
    Activations: batch over data(+pod), heads/vocab/experts over model.
    ``seq_sharded_cache`` moves the decode KV cache's sequence dim onto the
    model axis (ring-free sequence sharding — see EXPERIMENTS.md §Perf).
    ``sp_activations`` shards the token dim of norm/elementwise regions over
    the model axis (Megatron-style sequence parallelism).
    """
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    if serve:
        # Decode-optimized: weights stay 2-D sharded (embed x model), but the
        # ACTIVATION model dim is sharded over the data axis too, so dense
        # layers contract locally and psum tiny (tokens x out) partials
        # instead of all-gathering whole weight matrices every step
        # (EXPERIMENTS.md perf log, nemotron decode: 369 GB -> MBs of wire).
        return {
            "embed": dp, "heads": "model", "kv_heads": "model",
            "mlp": "model", "vocab": "model", "expert": "model",
            "expert_mlp": None, "layers": None, "conv": None, "ssm": None,
            "act_batch": None,          # decode batch is tiny; replicate
            "act_seq": None,
            "act_embed": dp,            # contraction-sharded activations
            "act_heads": "model",
            "act_kv_heads": "model",
            "act_mlp": "model",
            "act_vocab": "model",
            "act_expert": "model",
            "cache_seq": "model" if seq_sharded_cache else None,
            "cache_batch": dp,
            "frames": None,
        }
    rules: Dict[str, AxisRule] = {
        # weight dims
        "embed": dp,          # FSDP shard of the contraction dim
        "heads": "model",
        "kv_heads": "model",  # degrades to None when not divisible
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "layers": None,
        "conv": None,
        "ssm": None,
        # activation dims
        "act_batch": dp,
        "act_seq": "model" if sp_activations else None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_expert": "model",
        "cache_seq": "model" if seq_sharded_cache else None,
        "cache_batch": dp,
        "frames": None,
    }
    return rules


# ---------------------------------------------------------------------------
# Sharder
# ---------------------------------------------------------------------------

class Sharder:
    def __init__(self, mesh: Mesh, rules: Dict[str, AxisRule]):
        self.mesh = mesh
        self.rules = dict(rules)
        self._axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _axes_for(self, name: Optional[str]) -> Tuple[str, ...]:
        if name is None:
            return ()
        rule = self.rules.get(name)
        if rule is None:
            return ()
        if isinstance(rule, str):
            rule = (rule,)
        return tuple(a for a in rule if a in self._axis_sizes)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor with the given logical axes.

        With ``shape`` provided, axes that do not divide the dim are dropped
        (partial tuples are trimmed greedily from the right).
        """
        parts = []
        used = set()
        for d, name in enumerate(logical_axes):
            axes = tuple(a for a in self._axes_for(name) if a not in used)
            if shape is not None and axes:
                dim = shape[d]
                while axes and dim % int(np.prod([self._axis_sizes[a] for a in axes])) != 0:
                    axes = axes[:-1]
            used.update(axes)
            if not axes:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, logical_axes):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical_axes, x.shape)))


_tls = threading.local()


def current_sharder() -> Optional[Sharder]:
    return getattr(_tls, "sharder", None)


@contextlib.contextmanager
def use_sharder(sharder: Optional[Sharder]):
    prev = current_sharder()
    _tls.sharder = sharder
    try:
        yield sharder
    finally:
        _tls.sharder = prev


def constrain(x, logical_axes: Sequence[Optional[str]]):
    """Ambient sharding constraint; identity when no sharder is active."""
    s = current_sharder()
    if s is None:
        return x
    return s.constrain(x, logical_axes)


def tree_shardings(sharder: Sharder, params, axes_tree_):
    """Pytree of NamedShardings for a param tree + congruent axes tree."""
    # tree structure follows ``params``; the congruent axes-tuple node is
    # handed to the mapper whole (flatten_up_to semantics).
    return jax.tree.map(
        lambda p, a: sharder.sharding(a, getattr(p, "shape", None)),
        params, axes_tree_)
