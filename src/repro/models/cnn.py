"""The paper's evaluation CNNs: LeNet-5, VGG-16 (variation D, 2 FC), VGG-8.

All convolutions/FCs route through the DAISM GEMM (im2col — exactly how the
accelerator consumes them: kernels flattened into SRAM rows, paper Fig 4),
so Table-2 accuracy experiments exercise the same numerics the multiplier
tests validate bit-level.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.policy import OpKind, policy_conv2d, policy_dot, site_scope

from .common import ArchConfig
from .module import Ctx, he_init, lecun_init, zeros_init


def _conv(ctx: Ctx, name: str, x, cout: int, cfg: ArchConfig, *, k: int = 3,
          init=None):
    cin = x.shape[-1]
    w = ctx.param(name, (k, k, cin, cout), cfg.param_dtype,
                  init or lecun_init(), axes=(None, None, None, None))
    b = ctx.param(name + "_b", (cout,), cfg.param_dtype, zeros_init(),
                  axes=(None,))
    y = policy_conv2d(cfg.approx_policy, x, w, name=name, padding="SAME",
                      record=ctx.mode == "apply")
    return y + b.astype(x.dtype)


def _fc(ctx: Ctx, name: str, x, dout: int, cfg: ArchConfig,
        kind: OpKind = OpKind.DENSE):
    din = x.shape[-1]
    w = ctx.param(name, (din, dout), cfg.param_dtype, lecun_init(),
                  axes=(None, None))
    b = ctx.param(name + "_b", (dout,), cfg.param_dtype, zeros_init(),
                  axes=(None,))
    y = policy_dot(cfg.approx_policy, x, w, name=name, kind=kind,
                   record=ctx.mode == "apply")
    return y + b.astype(x.dtype)


def _pool(x):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                             (1, 2, 2, 1), "VALID")


def lenet5(ctx: Ctx, images: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """images: (B, 28, 28, 1) -> logits (B, 10)."""
    x = images.astype(cfg.compute_dtype)
    x = jnp.tanh(_conv(ctx, "c1", x, 6, cfg, k=5))
    x = _pool(x)
    x = jnp.tanh(_conv(ctx, "c2", x, 16, cfg, k=5))
    x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jnp.tanh(_fc(ctx, "f1", x, 120, cfg))
    x = jnp.tanh(_fc(ctx, "f2", x, 84, cfg))
    return _fc(ctx, "out", x, cfg.vocab, cfg,
               kind=OpKind.LM_HEAD).astype(jnp.float32)


_VGG16 = (64, 64, "P", 128, 128, "P", 256, 256, 256, "P",
          512, 512, 512, "P", 512, 512, 512, "P")
_VGG8 = (64, "P", 128, "P", 256, "P", 512, "P", 512, "P")


def _vgg(ctx: Ctx, images, cfg: ArchConfig, plan: Sequence, fc_dim: int):
    x = images.astype(cfg.compute_dtype)
    i = 0
    for item in plan:
        if item == "P":
            x = _pool(x)
        else:
            # He init: a 16-layer plain-ReLU stack needs gain-2 init to
            # train without normalization (as the original VGG recipe did)
            x = jax.nn.relu(_conv(ctx, f"c{i}", x, item, cfg,
                                  init=he_init()))
            i += 1
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(_fc(ctx, "f1", x, fc_dim, cfg))
    return _fc(ctx, "out", x, cfg.vocab, cfg,
               kind=OpKind.LM_HEAD).astype(jnp.float32)


def vgg16(ctx: Ctx, images, cfg: ArchConfig):
    """VGG-16 variation D with 2 FC layers (paper §5.1.1), CIFAR10 32x32."""
    return _vgg(ctx, images, cfg, _VGG16, 512)


def vgg8(ctx: Ctx, images, cfg: ArchConfig):
    return _vgg(ctx, images, cfg, _VGG8, 512)


class CNNModel:
    """Uniform wrapper matching the LM model API (no decode path)."""

    _FNS = {"lenet5": lenet5, "vgg16": vgg16, "vgg8": vgg8}

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.fn = self._FNS[cfg.name.split("-")[0]]

    def init(self, rng, *, abstract: bool = False, image_shape=None):
        shape = image_shape or ((1, 28, 28, 1) if "lenet" in self.cfg.name
                                else (1, 32, 32, 3))

        def build(rng_):
            ctx = Ctx("init", rng=rng_)
            with site_scope("cnn"):
                self.fn(ctx, jnp.zeros(shape, self.cfg.compute_dtype),
                        self.cfg)
            return ctx.params, ctx.axes

        if abstract:
            holder = {}

            def f(r):
                p, a = build(r)
                holder.update(a)
                return p

            return jax.eval_shape(f, rng), holder
        return build(rng)

    def forward(self, params, batch):
        ctx = Ctx("apply", params=params)
        with site_scope("cnn"):
            out = self.fn(ctx, batch["images"], self.cfg)
        return out, jnp.zeros((), jnp.float32)
