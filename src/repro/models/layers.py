"""Common neural layers with pluggable (exact | DAISM) matmul backend.

Every parameter GEMM routes through :func:`dense`, which resolves its
numerics per op-site through the architecture's injectable approximation
policy (``cfg.approx_policy``, see :mod:`repro.policy`) — the paper's
technique as a first-class framework feature, addressable per layer
(DESIGN.md §2). Dynamic attention GEMMs (qk^T, att@v) default to exact —
DAISM multiplies a *stationary* SRAM-resident operand against streamed
inputs, and neither attention operand is stationary — but a policy rule
carrying the ``:flash`` token (``*/attn/*=pc3_tr:flash``) opts the
``.../attn/kernel`` site (OpKind.ATTN_QK) into the fused Pallas
flash-attention kernel, where scores and (optionally approximate) products
stay VMEM-resident. Cached decode shapes always fall back to the exact jnp
path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.parallel.sharding import constrain, current_sharder
from repro.parallel.unroll import unroll_for
from repro.policy import OpKind, attention_kernel, policy_dot, resolve_site

from .common import ArchConfig
from .module import Ctx, lecun_init, normal_init, ones_init, zeros_init

# ---------------------------------------------------------------------------
# Dense / norms
# ---------------------------------------------------------------------------

def dense(ctx: Ctx, name: str, x: jnp.ndarray, d_out: int, cfg: ArchConfig,
          *, axes=("embed", "mlp"), use_bias: bool = False,
          init=None, kind: OpKind = OpKind.DENSE) -> jnp.ndarray:
    d_in = x.shape[-1]
    w = ctx.param(name, (d_in, d_out), cfg.param_dtype,
                  init or lecun_init(), axes=axes)
    # init-mode traces run outside the model's site scopes (their outputs
    # are discarded), so only apply-mode resolutions are recorded
    out = policy_dot(cfg.approx_policy, x, w, name=name, kind=kind,
                     record=ctx.mode == "apply")
    if use_bias:
        b = ctx.param(name + "_b", (d_out,), cfg.param_dtype, zeros_init(),
                      axes=(axes[-1],))
        out = out + b.astype(out.dtype)
    return out


def norm(ctx: Ctx, name: str, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    d = x.shape[-1]
    scale = ctx.param(name + "_scale", (d,), "float32", ones_init(),
                      axes=("act_embed",))
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        bias = ctx.param(name + "_bias", (d,), "float32", zeros_init(),
                         axes=("act_embed",))
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + 1e-5) * scale + bias
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * lax.rsqrt(ms + 1e-6) * scale
    return y.astype(x.dtype)


def activate(h: jnp.ndarray, g: Optional[jnp.ndarray], act: str) -> jnp.ndarray:
    if act == "swiglu":
        return jax.nn.silu(g) * h
    if act == "geglu":
        return jax.nn.gelu(g) * h
    if act == "relu2":
        r = jax.nn.relu(h)
        return r * r
    if act == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(act)


def mlp(ctx: Ctx, x: jnp.ndarray, cfg: ArchConfig, d_ff: Optional[int] = None,
        *, use_bias: bool = False) -> jnp.ndarray:
    d_ff = d_ff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    h = dense(ctx, "wi", x, d_ff, cfg, axes=("embed", "mlp"), use_bias=use_bias)
    g = dense(ctx, "wg", x, d_ff, cfg, axes=("embed", "mlp")) if gated else None
    h = activate(h, g, cfg.act)
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    return dense(ctx, "wo", h, x.shape[-1], cfg, axes=("mlp", "embed"),
                 use_bias=use_bias)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (online-softmax over KV chunks; causal / window / cross)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           q_pos: jnp.ndarray, kv_pos: jnp.ndarray, *,
           causal: bool, window: int = 0, chunk: int = 1024,
           softcap: float = 0.0, unroll_category: str = "attn",
           score_dtype=jnp.float32, policy=None,
           record: bool = True) -> jnp.ndarray:
    """Online-softmax attention (never materializes the full S x S matrix).

    q: (B, Sq, H, D); k, v: (B, Skv, KH, D); *_pos: (Sq,) / (Skv,) absolute
    positions used for causal/window masking (decode passes a 1-length q_pos).
    Either may instead be (B, Sq) / (B, Skv) for per-row positions — the
    slot-cache serving path, where every batch row is an independent request
    at its own sequence offset (masks then cost an extra batch dim, so the
    shared-position fast path is kept for train/prefill).

    With ``policy`` set, the call resolves the ambient ``kernel`` site
    (OpKind.ATTN_QK) and, when the effective config requests the flash
    kernel and the shape is eligible (shared 1-D positions, no window, no
    softcap, and sq == skv when causal — the kernel masks by index, which
    matches position masking for the monotone position vectors every
    non-cached path uses), dispatches to the fused Pallas flash attention.
    Ineligible shapes (windowed, softcapped, per-row serving, cached decode)
    resolve — and are recorded — as EXACT and take the jnp path below.
    """
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    if policy is not None:
        flash_ok = (jnp.ndim(q_pos) == 1 and jnp.ndim(kv_pos) == 1
                    and window == 0 and softcap == 0.0
                    and (not causal or sq == skv))
        macs = 2 * b * h * sq * skv * d  # qk^T + att@v
        # dims of one head's qk^T contraction (the flash kernel's grid unit)
        site_cfg = resolve_site(policy, "kernel", OpKind.ATTN_QK, q.dtype,
                                record=record, macs=macs,
                                dims=(sq, d, skv),
                                attn_eligible=flash_ok)
        if flash_ok and site_cfg.attn_kernel == "flash":
            return attention_kernel(site_cfg)(q, k, v, causal)
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scale = 1.0 / np.sqrt(d)
    sd = jnp.dtype(score_dtype)
    qf = (q.astype(jnp.float32) * scale).astype(sd)

    per_row = jnp.ndim(q_pos) == 2 or jnp.ndim(kv_pos) == 2
    if per_row:
        q_pos = jnp.broadcast_to(
            q_pos if jnp.ndim(q_pos) == 2 else q_pos[None], (b, sq))
        kv_pos = jnp.broadcast_to(
            kv_pos if jnp.ndim(kv_pos) == 2 else kv_pos[None], (b, skv))

    chunk = min(chunk, skv)
    n_chunks = int(np.ceil(skv / chunk))
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos,
                         ((0, 0), (0, pad)) if per_row else (0, pad),
                         constant_values=2**30)
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = (kv_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
          if per_row else kv_pos.reshape(n_chunks, chunk))

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs  # (B, C, H, D), (B, C, H, D), (C,) | (B, C)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(sd),
                       preferred_element_type=sd)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        if per_row:  # (B, Sq, C) masks from (B, C) x (B, Sq) positions
            mask = jnp.ones((b, sq, kb.shape[1]), bool)
            if causal:
                mask &= pb[:, None, :] <= q_pos[:, :, None]
            if window > 0:
                mask &= pb[:, None, :] > (q_pos[:, :, None] - window)
            mask &= pb[:, None, :] < 2**30  # padding
            mask = mask[:, None]            # broadcast over heads
        else:
            mask = jnp.ones((sq, kb.shape[1]), bool)
            if causal:
                mask &= pb[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= pb[None, :] > (q_pos[:, None] - window)
            mask &= pb[None, :] < 2**30  # padding
            mask = mask[None, None]
        s = jnp.where(mask, s, jnp.asarray(-1e30, sd))
        m_new = jnp.maximum(m, s.max(-1).astype(jnp.float32))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]).astype(sd)
        l_new = l * corr + p.astype(jnp.float32).sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(sd),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, pc),
                              unroll=min(unroll_for(unroll_category), n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Sq, H, D)


def _paged_kv_attend(q, k, v, ck, cv, widx, phys_read, positions, *,
                     causal, window, chunk, softcap, unroll_category):
    """Scatter new K/V into the physical page pool, gather each row's pages,
    attend. Head-local by construction (no cross-head reduction), so it runs
    unchanged as a shard_map body with q/k/v/pool split over the head dims —
    the block-table gather/scatter stays on-shard."""
    p_cells = ck.shape[0]
    ck = ck.at[widx].set(k.astype(ck.dtype), mode="drop")
    cv = cv.at[widx].set(v.astype(cv.dtype), mode="drop")
    idx = jnp.minimum(phys_read, p_cells - 1)
    gk = jnp.take(ck, idx, axis=0)  # (B, K, KH, HD)
    gv = jnp.take(cv, idx, axis=0)
    out = attend(q, gk, gv, positions, jnp.arange(gk.shape[1]),
                 causal=causal, window=window, chunk=chunk, softcap=softcap,
                 unroll_category=unroll_category)
    return out, ck, cv


def _paged_shard_axis(sharder, q_shape, pool_shape) -> Optional[str]:
    """Mesh axis the paged-attention shard_map splits heads over, or None.

    Eligible only when the sharder lands the *same single* mesh axis on
    both the activation heads dim and the pool's kv_heads dim (its
    divisibility fallback already dropped axes that do not divide, so an
    indivisible head count degrades to the replicated GSPMD path rather
    than an error)."""
    if sharder is None:
        return None
    qspec = sharder.spec((None, None, "act_heads", None), q_shape)
    pspec = sharder.spec((None, "act_kv_heads", None), pool_shape)
    axq = qspec[2] if len(qspec) > 2 else None
    axp = pspec[1] if len(pspec) > 1 else None
    return axq if isinstance(axq, str) and axq == axp else None


def self_attention(ctx: Ctx, x: jnp.ndarray, cfg: ArchConfig, *,
                   positions: jnp.ndarray, cache: Optional[dict] = None,
                   causal: bool = True, n_heads: int = 0, kv_heads: int = 0,
                   head_dim: int = 0, use_bias: bool = False,
                   unroll_category: str = "attn"
                   ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """GQA self-attention. With ``cache`` (decode) appends K/V at
    ``cache['pos']`` and attends over the whole cache."""
    nh = n_heads or cfg.n_heads
    kh = kv_heads or cfg.kv_heads
    hd = head_dim or cfg.head_dim
    b, s, _ = x.shape
    q = dense(ctx, "wq", x, nh * hd, cfg, axes=("embed", "heads"),
              use_bias=use_bias).reshape(b, s, nh, hd)
    k = dense(ctx, "wk", x, kh * hd, cfg, axes=("embed", "kv_heads"),
              use_bias=use_bias).reshape(b, s, kh, hd)
    v = dense(ctx, "wv", x, kh * hd, cfg, axes=("embed", "kv_heads"),
              use_bias=use_bias).reshape(b, s, kh, hd)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("act_batch", "act_seq", "act_heads", None))

    new_cache = None
    if cache is not None and "write_idx" in cache:
        # paged KV cache (repro.serve): per-layer physical page pool
        # k/v (P, KH, HD) where P = num_blocks * block_size; the request's
        # block table is pre-resolved by DecoderLM.paged_step into
        #   write_idx (B, S): physical cell of each new token (>= P: drop —
        #     padding rows / chunk padding beyond the reservation), and
        #   phys_read (B, K): physical cell of every *logical* kv position
        #     0..K-1 (clipped gather; unmapped entries land beyond the
        #     row's write position, so the causal mask excludes them).
        ck, cv = cache["k"], cache["v"]
        widx, phys_read = cache["write_idx"], cache["phys_read"]
        body = functools.partial(
            _paged_kv_attend, causal=causal, window=cfg.window,
            chunk=cfg.attn_chunk, softcap=cfg.logit_softcap,
            unroll_category=unroll_category)
        sharder = current_sharder()
        ax = _paged_shard_axis(sharder, q.shape, ck.shape)
        if ax is not None:
            # tensor-parallel serving: shard_map over the head dims keeps
            # every pool scatter/gather local to its shard; attend() is
            # per-head so the body needs no collectives (GQA grouping is
            # contiguous: q heads [j*nh/n, ...) read kv heads [j*kh/n, ...))
            from jax.sharding import PartitionSpec as P

            from repro.compat import shard_map

            hspec = P(None, None, ax, None)
            pspec = P(None, ax, None)
            out, ck, cv = shard_map(
                body, mesh=sharder.mesh,
                in_specs=(hspec, hspec, hspec, pspec, pspec, P(), P(), P()),
                out_specs=(hspec, pspec, pspec),
                check_vma=False)(q, k, v, ck, cv, widx, phys_read, positions)
        else:
            out, ck, cv = body(q, k, v, ck, cv, widx, phys_read, positions)
        ck = constrain(ck, ("cache_seq", "act_kv_heads", None))
        cv = constrain(cv, ("cache_seq", "act_kv_heads", None))
        out = out.reshape(b, s, nh * hd)
        out = dense(ctx, "wo", out, x.shape[-1], cfg, axes=("heads", "embed"),
                    use_bias=use_bias)
        return out, dict(k=ck, v=cv)
    if cache is not None:
        ck, cv, pos = cache["k"], cache["v"], cache["pos"]
        size = ck.shape[1]
        ring = "abs_pos" in cache
        if jnp.ndim(pos) == 1:  # per-slot cache: row i writes at pos[i]
            if ring:
                raise NotImplementedError(
                    "per-slot caches do not support ring/window buffers")
            row_update = jax.vmap(
                lambda cr, kr, p: lax.dynamic_update_slice(cr, kr, (p, 0, 0)))
            ck = row_update(ck, k.astype(ck.dtype), pos)
            cv = row_update(cv, v.astype(cv.dtype), pos)
        else:
            slot = lax.rem(pos, size) if ring else pos
            ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
            cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        ck = constrain(ck, ("cache_batch", "cache_seq", "act_kv_heads", None))
        cv = constrain(cv, ("cache_batch", "cache_seq", "act_kv_heads", None))
        new_cache = dict(k=ck, v=cv, pos=pos + s)
        if ring:
            ap = lax.dynamic_update_slice(
                cache["abs_pos"], positions.astype(jnp.int32), (slot,))
            new_cache["abs_pos"] = ap
            kv_pos = jnp.where(ap < 0, 2**30, ap)  # empty slots masked out
        else:
            kv_pos = jnp.arange(size)
        out = attend(q, ck, cv, positions, kv_pos, causal=causal,
                     window=cfg.window, chunk=cfg.attn_chunk,
                     softcap=cfg.logit_softcap,
                     unroll_category=unroll_category)
    else:
        out = attend(q, k, v, positions, positions, causal=causal,
                     window=cfg.window, chunk=cfg.attn_chunk,
                     softcap=cfg.logit_softcap,
                     unroll_category=unroll_category,
                     score_dtype=cfg.attn_score_dtype,
                     policy=cfg.approx_policy,
                     record=ctx.mode == "apply")
    out = out.reshape(b, s, nh * hd)
    out = dense(ctx, "wo", out, x.shape[-1], cfg, axes=("heads", "embed"),
                use_bias=use_bias)
    return out, new_cache


def cross_attention(ctx: Ctx, x: jnp.ndarray, kv_src: jnp.ndarray,
                    cfg: ArchConfig, *, use_bias: bool = False) -> jnp.ndarray:
    """Full (non-causal) cross attention against encoder/image states."""
    nh, kh, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    b, s, _ = x.shape
    skv = kv_src.shape[1]
    q = dense(ctx, "wq", x, nh * hd, cfg, axes=("embed", "heads"),
              use_bias=use_bias).reshape(b, s, nh, hd)
    k = dense(ctx, "wk", kv_src, kh * hd, cfg, axes=("embed", "kv_heads"),
              use_bias=use_bias).reshape(b, skv, kh, hd)
    v = dense(ctx, "wv", kv_src, kh * hd, cfg, axes=("embed", "kv_heads"),
              use_bias=use_bias).reshape(b, skv, kh, hd)
    out = attend(q, k, v, jnp.arange(s), jnp.arange(skv), causal=False,
                 chunk=skv,  # single chunk: small KV, uniform attn trips
                 score_dtype=cfg.attn_score_dtype,
                 policy=cfg.approx_policy,
                 record=ctx.mode == "apply")
    out = out.reshape(b, s, nh * hd)
    return dense(ctx, "wo", out, x.shape[-1], cfg, axes=("heads", "embed"),
                 use_bias=use_bias)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(ctx: Ctx, tokens: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    e = ctx.param("embedding", (cfg.vocab, cfg.d_model), cfg.param_dtype,
                  normal_init(1.0), axes=("vocab", "embed"))
    x = jnp.take(e, tokens, axis=0)
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def unembed(ctx: Ctx, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        e = ctx.param("embedding", (cfg.vocab, cfg.d_model), cfg.param_dtype,
                      normal_init(1.0), axes=("vocab", "embed"))
        logits = policy_dot(cfg.approx_policy, x, e.T, name="lm_head",
                            kind=OpKind.LM_HEAD,
                            record=ctx.mode == "apply")
    else:
        logits = dense(ctx, "lm_head", x, cfg.vocab, cfg,
                       axes=("embed", "vocab"), kind=OpKind.LM_HEAD)
    return constrain(logits, ("act_batch", "act_seq", "act_vocab"))

