"""Decoder-only transformer family: dense, MoE, VLM (cross-attn), enc-dec.

Layer stacks are *stacked-parameter scans* (MaxText-style): one layer's
params are initialized under ``jax.vmap`` over a leading ``layers`` axis and
consumed with ``lax.scan``, keeping HLO size O(1) in depth — essential for
96-layer/340B dry-runs on a 512-device mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import constrain
from repro.parallel.unroll import unroll_for
from repro.policy import OpKind, plan_segments, site_scope

from .common import ArchConfig
from .layers import (cross_attention, dense, embed, mlp, norm,
                     self_attention, unembed)
from .module import Ctx, apply_model, init_model
from .moe import moe_ffn

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Op-site probes (policy segmentation)
# ---------------------------------------------------------------------------

def mlp_sites(cfg: ArchConfig, base: str):
    """(path, kind) probe sites of one dense MLP under ``base``."""
    names = ("wi", "wg", "wo") if cfg.act in ("swiglu", "geglu") else \
        ("wi", "wo")
    return [(f"{base}/{n}", OpKind.DENSE) for n in names]


def attn_sites(base: str):
    sites = [(f"{base}/{n}", OpKind.DENSE) for n in ("wq", "wk", "wv", "wo")]
    # the dynamic qk^T/att@v contraction pair resolves as one ATTN_QK site
    # (models/layers.attend) — it must be probed too, or a depth rule that
    # only changes attention dispatch would be invisible to segmentation
    sites.append((f"{base}/kernel", OpKind.ATTN_QK))
    return sites


def decoder_block_sites(cfg: ArchConfig, i: int, prefix: str = "decoder"):
    """Every contraction site of decoder layer ``i`` — must mirror the paths
    the traced block produces (Ctx scopes + dense leaf names)."""
    base = f"{prefix}/layer_{i}"
    sites = attn_sites(f"{base}/attn")
    if cfg.n_experts:
        names = ("w_in", "w_gate", "w_out") if cfg.act in ("swiglu", "geglu") \
            else ("w_in", "w_out")
        sites += [(f"{base}/ffn/{n}", OpKind.MOE_EXPERT) for n in names]
    else:
        sites += mlp_sites(cfg, f"{base}/ffn")
    return sites


def clip_segments(segments, lo: int, hi: int):
    """Intersect policy segments with the layer range [lo, hi)."""
    return tuple((max(a, lo), min(b, hi))
                 for a, b in segments if a < hi and b > lo)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def decoder_block(ctx: Ctx, cfg: ArchConfig, x, *, positions, cache=None,
                  causal=True):
    """Pre-norm self-attention + FFN (dense or MoE). Returns (x, cache, aux)."""
    use_bias = cfg.norm == "layernorm"  # starcoder2/whisper-style
    with ctx.scope("attn"):
        h, new_cache = self_attention(
            ctx, norm(ctx, "ln1", x, cfg), cfg, positions=positions,
            cache=cache, causal=causal, use_bias=use_bias)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    with ctx.scope("ffn"):
        y = norm(ctx, "ln2", x, cfg)
        if cfg.n_experts:
            h, aux = moe_ffn(ctx, y, cfg)
        else:
            h = mlp(ctx, y, cfg, use_bias=use_bias)
    x = x + h
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x, new_cache, aux


def cross_block(ctx: Ctx, cfg: ArchConfig, x, kv_src):
    """Cross-attention block (VLM / whisper decoder insert)."""
    with ctx.scope("xattn"):
        h = cross_attention(ctx, norm(ctx, "ln1", x, cfg), kv_src, cfg)
    x = x + h
    with ctx.scope("ffn"):
        x = x + mlp(ctx, norm(ctx, "ln2", x, cfg), cfg)
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


# ---------------------------------------------------------------------------
# Stacked-layer machinery
# ---------------------------------------------------------------------------

def stacked_init(layer_fn, rng, n_layers: int, *args, **kw):
    """Init a layer stack: returns (stacked_params, axes with 'layers' prepended)."""
    keys = jax.random.split(rng, n_layers)
    holder = {}

    def one(k):
        ctx = Ctx("init", rng=k)
        layer_fn(ctx, *args, **kw)
        holder["axes"] = ctx.axes
        return ctx.params

    params = jax.vmap(one)(keys)
    axes = {path: ("layers",) + a for path, a in holder["axes"].items()}
    return params, axes


def apply_remat(fn, remat: str):
    """Wrap a params-level function (pytree args only) with a remat policy.

    'dots'    saves every matmul output (incl. batched attention scores);
    'dots_nb' saves only non-batched matmuls (weight GEMMs) — attention
              scores are recomputed, the sweet spot found in the §Perf log;
    'full'    recomputes everything.
    """
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if remat == "dots_nb":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if remat == "full":
        return jax.checkpoint(fn)
    return fn


def scan_layers(layer_fn, stacked_params, x, *, cache=None,
                unroll: int = 0, remat: str = "none", **kw):
    """Run x through a stacked-param layer scan.

    cache (optional): pytree stacked on layer dim; scanned alongside params
    and the per-layer updated cache is emitted as a stacked output.
    remat: activation checkpoint policy applied per layer (params-level, so
    jax.checkpoint sees only pytree arguments).
    """
    inner = apply_remat(
        lambda p, h, c: apply_model(layer_fn, p, h, cache=c, **kw), remat)

    def body(carry, layer_in):
        h, aux_acc = carry
        p, c = layer_in
        h, new_c, aux = inner(p, h, c)
        return (h, aux_acc + aux), new_c

    (x, aux), new_cache = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked_params, cache),
        unroll=unroll or unroll_for("layers"))
    return x, new_cache, aux


def scan_policy_segments(layer_fn, stacked_params, x, *, segments,
                         base: int = 0, cache=None, remat: str = "none",
                         prefix: str = "layer", **kw):
    """Run consecutive ``scan_layers`` segments, one per policy segment.

    ``segments`` are (lo, hi) *global* layer ranges (plan_segments); the
    stacked params/cache are indexed relative to ``base`` (the global index
    of their row 0). Each segment is traced under the site scope
    ``{prefix}_{lo}``, so per-depth policy rules resolve against the
    segment's first layer — valid because every layer in a segment resolves
    identically by construction. A uniform policy yields one segment and
    the exact HLO the un-segmented scan produced.
    """
    aux_total = jnp.zeros((), jnp.float32)
    parts = []
    for lo, hi in segments:
        sub = jax.tree.map(lambda p: p[lo - base:hi - base], stacked_params)
        subc = (None if cache is None else
                jax.tree.map(lambda c: c[lo - base:hi - base], cache))
        with site_scope(f"{prefix}_{lo}", repeat=hi - lo):
            x, nc, aux = scan_layers(layer_fn, sub, x, cache=subc,
                                     remat=remat, **kw)
        aux_total = aux_total + aux
        parts.append(nc)
    new_cache = None
    if cache is not None:
        new_cache = (parts[0] if len(parts) == 1 else
                     jax.tree.map(lambda *t: jnp.concatenate(t, 0), *parts))
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Decoder-only LM (dense + MoE + VLM)
# ---------------------------------------------------------------------------

class DecoderLM:
    """Dense / MoE / VLM decoder LM with a uniform train/prefill/decode API."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.is_vlm = cfg.cross_every > 0
        # maximal layer runs with identical resolved numerics (one scan each)
        self.segments = plan_segments(
            cfg.approx_policy,
            functools.partial(decoder_block_sites, cfg), 0, cfg.n_layers)

    # -- init ------------------------------------------------------------
    def init(self, rng, *, abstract: bool = False):
        def build(rng_):
            k_embed, k_layers, k_cross, k_head = jax.random.split(rng_, 4)
            params: Params = {}
            axes = {}
            ctx = Ctx("init", rng=k_embed)
            embed(ctx, jnp.zeros((1, 1), jnp.int32), self.cfg)
            if not self.cfg.tie_embeddings:
                x0 = jnp.zeros((1, 1, self.cfg.d_model), self.cfg.compute_dtype)
                norm(ctx, "final_ln", x0, self.cfg)
                unembed(ctx, x0, self.cfg)
            else:
                norm(ctx, "final_ln",
                     jnp.zeros((1, 1, self.cfg.d_model), self.cfg.compute_dtype),
                     self.cfg)
            params.update(ctx.params)
            axes.update(ctx.axes)

            pos0 = jnp.zeros((1,), jnp.int32)
            x0 = jnp.zeros((1, 1, self.cfg.d_model), self.cfg.compute_dtype)
            lp, la = stacked_init(
                lambda c, xx: decoder_block(c, self.cfg, xx, positions=pos0),
                k_layers, self.cfg.n_layers, x0)
            params["blocks"] = lp
            axes.update({("blocks",) + p: a for p, a in la.items()})

            if self.is_vlm:
                kv0 = jnp.zeros((1, 1, self.cfg.d_model), self.cfg.compute_dtype)
                cp, ca = stacked_init(
                    lambda c, xx: cross_block(c, self.cfg, xx, kv0),
                    k_cross, self.n_cross, x0)
                params["cross_blocks"] = cp
                axes.update({("cross_blocks",) + p: a for p, a in ca.items()})
            return params, axes

        if abstract:
            axes_holder = {}

            def build_shapes(r):
                p, a = build(r)
                axes_holder.update(a)
                return p

            shapes = jax.eval_shape(build_shapes, rng)
            return shapes, axes_holder
        return build(rng)

    @property
    def n_cross(self) -> int:
        return self.cfg.n_layers // self.cfg.cross_every if self.is_vlm else 0

    # -- forward (train / prefill) ----------------------------------------
    def forward(self, params: Params, batch: Dict[str, jnp.ndarray]):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)
        ctx = Ctx("apply", params=params)

        layer = functools.partial(decoder_block, positions=positions,
                                  causal=True)
        layer_fn = lambda c, xx, cache=None: layer(c, cfg, xx, cache=cache)

        with site_scope("decoder"):
            x = embed(ctx, tokens, cfg)
            if not self.is_vlm:
                x, _, aux = scan_policy_segments(
                    layer_fn, params["blocks"], x, segments=self.segments,
                    remat=cfg.remat)
            else:
                img = batch["image_embeds"].astype(x.dtype)
                aux = jnp.zeros((), jnp.float32)
                per = cfg.cross_every
                for g in range(self.n_cross):
                    x, _, a = scan_policy_segments(
                        layer_fn, params["blocks"], x,
                        segments=clip_segments(self.segments, g * per,
                                               (g + 1) * per),
                        remat=cfg.remat)
                    aux = aux + a
                    cparams = jax.tree.map(lambda p: p[g],
                                           params["cross_blocks"])
                    cross_fn = apply_remat(
                        lambda cp, xx: apply_model(
                            lambda c, h: cross_block(c, cfg, h, img), cp, xx),
                        cfg.remat)
                    with site_scope(f"cross_{g}"):
                        x = cross_fn(cparams, x)
            x = norm(ctx, "final_ln", x, cfg)
            logits = unembed(ctx, x, cfg)
        return logits, aux

    # -- KV cache ----------------------------------------------------------
    def init_cache(self, batch_size: int, max_seq: int, *,
                   abstract: bool = False):
        cfg = self.cfg
        ring = bool(cfg.window) and cfg.window < max_seq
        size = min(cfg.window, max_seq) if ring else max_seq
        kshape = (cfg.n_layers, batch_size, size, cfg.kv_heads, cfg.head_dim)

        def mk(shape, dtype, fill=0):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dtype)
            return jnp.full(shape, fill, dtype)

        cache = {
            "k": mk(kshape, jnp.dtype(cfg.compute_dtype)),
            "v": mk(kshape, jnp.dtype(cfg.compute_dtype)),
            "pos": mk((), jnp.int32),
        }
        if ring:  # ring buffer: absolute position of each slot, -1 = empty
            cache["abs_pos"] = mk((cfg.n_layers, size), jnp.int32, fill=-1)
        return cache

    # -- paged KV cache (block tables; repro.serve) -------------------------
    def init_paged_cache(self, num_blocks: int, block_size: int, *,
                         abstract: bool = False):
        """Physical page pool: ``k/v (layers, num_blocks*block_size, KH, HD)``.

        Logical sequences live in ``repro.serve.kv_pool`` block tables; the
        pool itself has no batch dimension — concurrency is bounded by pages,
        not rows. Windowed (ring-buffer) models are not supported: a paged
        pool never rolls, it frees whole pages at retirement.
        """
        cfg = self.cfg
        if cfg.window:
            # backstop for direct callers; the serving engine rejects this
            # combination earlier via EngineConfig.validate_for_model
            raise ValueError(
                f"paged KV cache needs window=0 (got window={cfg.window}: "
                "ring buffers roll in place, pages are freed whole)")
        if self.is_vlm:
            raise NotImplementedError(
                "paged serving does not cover VLM cross-attention blocks")
        cells = num_blocks * block_size
        kshape = (cfg.n_layers, cells, cfg.kv_heads, cfg.head_dim)
        dt = jnp.dtype(cfg.compute_dtype)
        if abstract:
            return {"k": jax.ShapeDtypeStruct(kshape, dt),
                    "v": jax.ShapeDtypeStruct(kshape, dt)}
        return {"k": jnp.zeros(kshape, dt), "v": jnp.zeros(kshape, dt)}

    @staticmethod
    def paged_cache_axes():
        """Logical axes of each paged-pool leaf (``init_paged_cache`` k/v)
        for Sharder placement: layers and pool cells stay whole on every
        device, kv heads split over the model axis — the same split the
        paged attention shard_map uses, so block-table gather/scatter is
        always shard-local (repro.serve tensor-parallel serving)."""
        return ("layers", None, "act_kv_heads", None)

    def paged_step(self, params: Params, tokens: jnp.ndarray, cache, *,
                   block_size: int):
        """One fixed-shape step over block tables — decode (S=1), chunked
        prefill (S=chunk), and speculative verify (S=spec_k+1, see
        :meth:`paged_verify_step`) are the same trace family.

        tokens (B, S); cache holds the physical pools ``k/v`` from
        :meth:`init_paged_cache` plus per-call row metadata: ``block_tables``
        (B, MB) int32 page ids (-1 = unmapped) and ``pos`` (B,) — the row's
        write offset (its current logical length). Row ``i`` writes K/V for
        positions ``pos[i] .. pos[i]+S-1`` through its table and attends over
        its own gathered pages; writes that fall outside the mapped pages
        (padding rows, chunk padding past the reservation) are dropped, and
        unmapped reads are causally masked. Returns ``(logits (B, S, V),
        new {k, v})`` — the caller owns ``block_tables``/``pos``.
        """
        cfg = self.cfg
        bt, pos = cache["block_tables"], cache["pos"]
        b, s = tokens.shape
        mb = bt.shape[1]
        cells = cache["k"].shape[1]
        positions = pos[:, None] + jnp.arange(s)  # (B, S) absolute
        # physical cell of every logical kv position (B, MB*block_size)
        base = jnp.where(bt < 0, cells, bt * block_size)
        phys_read = (base[:, :, None] + jnp.arange(block_size)
                     ).reshape(b, mb * block_size)
        # physical cell of each written token; >= cells means "drop"
        lblk = positions // block_size
        wblk = jnp.take_along_axis(bt, jnp.minimum(lblk, mb - 1), axis=1)
        write_idx = jnp.where(
            (wblk < 0) | (lblk >= mb), cells,
            wblk * block_size + positions % block_size)

        ctx = Ctx("apply", params=params)
        layer_cache = {"k": cache["k"], "v": cache["v"]}

        def layer_fn(c, xx, cache=None):
            lc = dict(cache, write_idx=write_idx, phys_read=phys_read)
            return decoder_block(c, cfg, xx, positions=positions,
                                 cache=lc, causal=True)

        with site_scope("decoder"):
            x = embed(ctx, tokens, cfg)
            x, new_lc, _ = scan_policy_segments(
                layer_fn, params["blocks"], x, segments=self.segments,
                cache=layer_cache)
            x = norm(ctx, "final_ln", x, cfg)
            logits = unembed(ctx, x, cfg)
        return logits, new_lc

    def paged_verify_step(self, params: Params, tokens: jnp.ndarray, cache,
                          *, block_size: int):
        """Speculative-decoding verify: one batched :meth:`paged_step` over
        ``S = k+1`` candidate positions (joining S=1 decode and S=chunk
        prefill as the third fixed shape of the same trace family).

        ``tokens[:, 0]`` is each row's last *committed* token and
        ``tokens[:, 1:]`` the ``k`` draft tokens; ``cache``/``block_size``
        are as in :meth:`paged_step` (``pos`` = the row's committed length,
        so K/V for the whole candidate window scatters at its true
        position offsets). Because causal attention at candidate ``j``
        sees only the in-step K/V of candidates ``<= j`` plus the
        committed pool, the per-position greedy tokens are exactly what
        ``k+1`` sequential S=1 decode steps would have produced — the
        standard accept/reject + bonus-token argument.

        Returns ``(greedy (B, S) per-position argmax, n_acc (B,) accepted
        draft count = longest prefix with greedy[:, j] == tokens[:, j+1],
        new {k, v})``. The emitted tokens are ``greedy[:, :n_acc+1]`` (the
        ``+1`` is the bonus token from the verify logits at the last
        accepted position); K/V scattered past ``pos + n_acc`` belongs to
        rejected candidates and must be logically rolled back by the
        caller (the serving engine truncates the block table and lets the
        next window overwrite in place).
        """
        logits, new_kv = self.paged_step(params, tokens, cache,
                                         block_size=block_size)
        greedy = jnp.argmax(logits, -1)                     # (B, S)
        match = (greedy[:, :-1] == tokens[:, 1:]).astype(jnp.int32)
        n_acc = jnp.cumprod(match, axis=1).sum(axis=1)      # (B,)
        return greedy, n_acc, new_kv

    # -- cached forward (shared by decode_step / prefill) -------------------
    def _cached_forward(self, params: Params, tokens: jnp.ndarray, cache,
                        positions, pos,
                        image_embeds: Optional[jnp.ndarray] = None):
        """Embed -> cached layer stack -> logits. ``positions`` feeds rope and
        attention masking; ``pos`` is the cache write offset — a scalar
        (shared, legacy) or a (B,) vector (per-slot serving cache)."""
        cfg = self.cfg
        ctx = Ctx("apply", params=params)

        ring = "abs_pos" in cache
        layer_cache = {"k": cache["k"], "v": cache["v"]}
        if ring:
            layer_cache["abs_pos"] = cache["abs_pos"]

        def layer_fn(c, xx, cache=None):
            lc = dict(cache, pos=pos)
            xx, nc, aux = decoder_block(c, cfg, xx, positions=positions,
                                        cache=lc, causal=True)
            nc.pop("pos")
            return xx, nc, aux

        with site_scope("decoder"):
            x = embed(ctx, tokens, cfg)
            if not self.is_vlm:
                x, new_lc, _ = scan_policy_segments(
                    layer_fn, params["blocks"], x, segments=self.segments,
                    cache=layer_cache)
            else:
                img = image_embeds.astype(x.dtype)
                per = cfg.cross_every
                new_parts = []
                for g in range(self.n_cross):
                    x, nc, _ = scan_policy_segments(
                        layer_fn, params["blocks"], x,
                        segments=clip_segments(self.segments, g * per,
                                               (g + 1) * per),
                        cache=layer_cache)
                    new_parts.append(nc)
                    cparams = jax.tree.map(lambda p: p[g],
                                           params["cross_blocks"])
                    with site_scope(f"cross_{g}"):
                        x = apply_model(
                            lambda c, xx: cross_block(c, cfg, xx, img),
                            cparams, x)
                new_lc = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                      *new_parts)
            x = norm(ctx, "final_ln", x, cfg)
            logits = unembed(ctx, x, cfg)
        return logits, new_lc

    # -- decode (one token, KV cache) --------------------------------------
    def decode_step(self, params: Params, tokens: jnp.ndarray, cache,
                    image_embeds: Optional[jnp.ndarray] = None):
        """tokens: (B, 1). Returns (logits (B, 1, V), new cache).

        ``cache['pos']`` is a scalar (all rows at the same offset — the
        legacy single-request path) or a (B,) vector (slot cache: row i is
        an independent request at offset pos[i], see repro.serve)."""
        pos = cache["pos"]
        positions = pos[:, None] if jnp.ndim(pos) == 1 else jnp.reshape(
            pos, (1,))
        logits, new_lc = self._cached_forward(params, tokens, cache,
                                              positions, pos, image_embeds)
        return logits, dict(new_lc, pos=pos + 1)

    # -- prefill (whole prompt in one forward, KV cache) --------------------
    def prefill(self, params: Params, tokens: jnp.ndarray, cache,
                image_embeds: Optional[jnp.ndarray] = None):
        """Batched prompt ingestion: one forward writes the prompt K/V into
        the cache and returns full logits. tokens: (B, S) — right-padded
        prompts are fine: a pad entry at position p >= true_len is either
        overwritten by decode before position p is reached or excluded by
        the causal mask, so it is never attended.

        Returns (logits (B, S, V), new cache with pos advanced by S). A
        serving engine overwrites ``pos`` with per-row true lengths when it
        adopts the K/V into its slot pool.
        """
        if "abs_pos" in cache:
            raise NotImplementedError(
                "prefill does not support ring/window caches")
        pos = cache["pos"]
        if jnp.ndim(pos) != 0:
            raise ValueError("prefill expects a scalar-pos cache")
        s = tokens.shape[1]
        positions = pos + jnp.arange(s)
        logits, new_lc = self._cached_forward(params, tokens, cache,
                                              positions, pos, image_embeds)
        return logits, dict(new_lc, pos=pos + s)


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper backbone; conv/audio frontend is a stub per the
# assignment — input_specs provides precomputed frame embeddings)
# ---------------------------------------------------------------------------

def encoder_block(ctx: Ctx, cfg: ArchConfig, x, *, positions, cache=None):
    with ctx.scope("attn"):
        h, _ = self_attention(ctx, norm(ctx, "ln1", x, cfg), cfg,
                              positions=positions, causal=False,
                              use_bias=True, unroll_category="attn_enc")
    x = x + h
    with ctx.scope("ffn"):
        x = x + mlp(ctx, norm(ctx, "ln2", x, cfg), cfg, use_bias=True)
    x = constrain(x, ("act_batch", "frames", "act_embed"))
    return x, None, jnp.zeros((), jnp.float32)


def encdec_decoder_block(ctx: Ctx, cfg: ArchConfig, x, *, positions,
                         enc_kv, cache=None):
    use_bias = True
    with ctx.scope("attn"):
        h, new_cache = self_attention(ctx, norm(ctx, "ln1", x, cfg), cfg,
                                      positions=positions, cache=cache,
                                      causal=True, use_bias=use_bias)
    x = x + h
    with ctx.scope("xattn"):
        h = cross_attention(ctx, norm(ctx, "lnx", x, cfg), enc_kv, cfg,
                            use_bias=use_bias)
    x = x + h
    with ctx.scope("ffn"):
        x = x + mlp(ctx, norm(ctx, "ln2", x, cfg), cfg, use_bias=use_bias)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x, new_cache, jnp.zeros((), jnp.float32)


def encoder_block_sites(cfg: ArchConfig, i: int):
    base = f"encoder/layer_{i}"
    return attn_sites(f"{base}/attn") + mlp_sites(cfg, f"{base}/ffn")


def encdec_decoder_sites(cfg: ArchConfig, i: int):
    base = f"decoder/layer_{i}"
    return (attn_sites(f"{base}/attn") + attn_sites(f"{base}/xattn")
            + mlp_sites(cfg, f"{base}/ffn"))


class EncDecLM:
    """Whisper-style: transformer encoder over precomputed frame embeddings,
    causal decoder with per-layer cross attention."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        pol = cfg.approx_policy
        self.enc_segments = plan_segments(
            pol, functools.partial(encoder_block_sites, cfg),
            0, cfg.enc_layers)
        self.dec_segments = plan_segments(
            pol, functools.partial(encdec_decoder_sites, cfg),
            0, cfg.n_layers)

    def init(self, rng, *, abstract: bool = False):
        def build(rng_):
            ke, kd, kx = jax.random.split(rng_, 3)
            cfg = self.cfg
            params: Params = {}
            axes = {}
            ctx = Ctx("init", rng=kx)
            embed(ctx, jnp.zeros((1, 1), jnp.int32), cfg)
            x0 = jnp.zeros((1, 1, cfg.d_model), cfg.compute_dtype)
            norm(ctx, "final_ln", x0, cfg)
            unembed(ctx, x0, cfg)
            # learned positional embeddings for frames (frontend stub)
            ctx.param("enc_pos", (cfg.enc_frames, cfg.d_model),
                      cfg.param_dtype, axes=("frames", "embed"))
            params.update(ctx.params)
            axes.update(ctx.axes)
            pos0 = jnp.zeros((1,), jnp.int32)
            ep, ea = stacked_init(
                lambda c, xx: encoder_block(c, cfg, xx, positions=pos0),
                ke, cfg.enc_layers, x0)
            params["enc_blocks"] = ep
            axes.update({("enc_blocks",) + p: a for p, a in ea.items()})
            dp, da = stacked_init(
                lambda c, xx: encdec_decoder_block(c, cfg, xx, positions=pos0,
                                                   enc_kv=x0),
                kd, cfg.n_layers, x0)
            params["dec_blocks"] = dp
            axes.update({("dec_blocks",) + p: a for p, a in da.items()})
            return params, axes

        if abstract:
            axes_holder = {}

            def build_shapes(r):
                p, a = build(r)
                axes_holder.update(a)
                return p

            shapes = jax.eval_shape(build_shapes, rng)
            return shapes, axes_holder
        return build(rng)

    def encode(self, params, frames):
        cfg = self.cfg
        ctx = Ctx("apply", params=params)
        pe = ctx.param("enc_pos", (cfg.enc_frames, cfg.d_model),
                       cfg.param_dtype, axes=("frames", "embed"))
        x = frames.astype(cfg.compute_dtype) + pe.astype(cfg.compute_dtype)
        positions = jnp.arange(frames.shape[1])
        enc_fn = lambda c, xx, cache=None: encoder_block(
            c, cfg, xx, positions=positions)
        with site_scope("encoder"):
            x, _, _ = scan_policy_segments(
                enc_fn, params["enc_blocks"], x, segments=self.enc_segments,
                remat=cfg.remat)
        return x

    def forward(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        positions = jnp.arange(tokens.shape[1])
        ctx = Ctx("apply", params=params)
        dec_fn = lambda c, xx, cache=None: encdec_decoder_block(
            c, cfg, xx, positions=positions, enc_kv=enc)
        with site_scope("decoder"):
            x = embed(ctx, tokens, cfg)
            x, _, _ = scan_policy_segments(
                dec_fn, params["dec_blocks"], x, segments=self.dec_segments,
                remat=cfg.remat)
            x = norm(ctx, "final_ln", x, cfg)
            logits = unembed(ctx, x, cfg)
        return logits, jnp.zeros((), jnp.float32)

    def init_cache(self, batch_size: int, max_seq: int, *,
                   abstract: bool = False):
        cfg = self.cfg

        def mk(shape, dtype):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dtype)
            return jnp.zeros(shape, dtype)

        kshape = (cfg.n_layers, batch_size, max_seq, cfg.kv_heads,
                  cfg.head_dim)
        dt = jnp.dtype(cfg.compute_dtype)
        return {
            "k": mk(kshape, dt), "v": mk(kshape, dt),
            "enc": mk((batch_size, cfg.enc_frames, cfg.d_model), dt),
            "pos": mk((), jnp.int32),
        }

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        pos = cache["pos"]
        positions = pos[None].reshape(1,)
        enc = cache["enc"]
        ctx = Ctx("apply", params=params)

        def layer_fn(c, xx, cache=None):
            lc = dict(k=cache["k"], v=cache["v"], pos=pos)
            xx, nc, aux = encdec_decoder_block(
                c, cfg, xx, positions=positions, enc_kv=enc, cache=lc)
            return xx, {"k": nc["k"], "v": nc["v"]}, aux

        with site_scope("decoder"):
            x = embed(ctx, tokens, cfg)
            x, new_lc, _ = scan_policy_segments(
                layer_fn, params["dec_blocks"], x,
                segments=self.dec_segments,
                cache={"k": cache["k"], "v": cache["v"]})
            x = norm(ctx, "final_ln", x, cfg)
            logits = unembed(ctx, x, cfg)
        return logits, {"k": new_lc["k"], "v": new_lc["v"], "enc": enc,
                        "pos": pos + 1}
