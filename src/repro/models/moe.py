"""Mixture-of-Experts FFN with expert parallelism (EP).

Two implementations:

* ``ep`` (production): ``shard_map`` (via repro.compat) over the mesh. Expert weights are
  2-D sharded — experts over the ``model`` axis, the contraction dim over the
  data(+pod) axes (FSDP) and all-gathered just-in-time. Each model rank
  dispatches its local tokens to *its own* expert slice with a static
  capacity buffer, runs the expert GEMMs, and the partial outputs are
  psum-combined over the model axis (same collective volume as a TP FFN
  all-reduce — the baseline we later hillclimb with all-to-all dispatch).
  Token-choice top-k routing with capacity dropping (Switch-style), combine
  weights applied on the output side.

* ``dense`` (reference): every expert on every token, gate-weighted. Used as
  the numerics oracle for the EP path in tests (with a capacity factor large
  enough that nothing drops, the two agree) and for smoke runs without a mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.sharding import current_sharder
from repro.policy import policy_expert_matmul

from .common import ArchConfig
from .layers import activate
from .module import Ctx, lecun_init


def _expert_mm(x: jnp.ndarray, w: jnp.ndarray, cfg: ArchConfig,
               name: str, record: bool = True) -> jnp.ndarray:
    """(E, C, d) x (E, d, f) -> (E, C, f), per-site DAISM via the policy."""
    return policy_expert_matmul(cfg.approx_policy, x, w, name=name,
                                record=record)


def _route(x2d: jnp.ndarray, router_w: jnp.ndarray, cfg: ArchConfig):
    """Token-choice top-k. Returns (ids (T,k), probs (T,k), aux_loss)."""
    logits = jnp.dot(x2d.astype(jnp.float32), router_w.astype(jnp.float32))
    probs_full = jax.nn.softmax(logits, axis=-1)          # (T, E)
    probs, ids = lax.top_k(probs_full, cfg.topk)          # (T, k)
    probs = probs / probs.sum(-1, keepdims=True)
    # Switch-style load-balance auxiliary loss.
    me = probs_full.mean(0)                                # (E,)
    ce = jnp.zeros((cfg.n_experts,)).at[ids.reshape(-1)].add(
        1.0 / ids.size)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return ids, probs, aux


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    c = int(np.ceil(tokens * cfg.topk / cfg.n_experts * cfg.capacity_factor))
    return max(c, cfg.topk)


def _local_dispatch_compute(x2d, ids, probs, w_in, w_gate, w_out, e0: int,
                            cfg: ArchConfig, record: bool = True):
    """Dispatch local tokens to the E_local experts [e0, e0+E_local), run
    them, and return the (partial) combined output (T, d)."""
    t, d = x2d.shape
    e_local = w_in.shape[0]
    cap = _capacity(t, cfg)
    flat_ids = ids.reshape(-1)                       # (T*k,)
    tok = jnp.arange(flat_ids.size) // cfg.topk      # owning token per slot
    le = flat_ids - e0
    mine = (le >= 0) & (le < e_local)
    le_safe = jnp.where(mine, le, 0)
    # position of each slot within its expert's capacity buffer
    oh = jax.nn.one_hot(jnp.where(mine, le, e_local), e_local + 1,
                        dtype=jnp.int32)             # (T*k, E_local+1)
    pos = jnp.cumsum(oh, axis=0) - 1
    pos = jnp.take_along_axis(pos, le_safe[:, None], axis=1)[:, 0]
    keep = mine & (pos < cap)
    pos_safe = jnp.where(keep, pos, cap)             # slot `cap` = trash row

    buf = jnp.zeros((e_local, cap + 1, d), x2d.dtype)
    buf = buf.at[le_safe, pos_safe].add(jnp.where(keep[:, None],
                                                  x2d[tok], 0))
    buf = buf[:, :cap]                               # (E_local, cap, d)

    gated = cfg.act in ("swiglu", "geglu")
    h = _expert_mm(buf, w_in, cfg, "w_in", record)
    g = _expert_mm(buf, w_gate, cfg, "w_gate", record) if gated else None
    h = activate(h, g, cfg.act)
    y = _expert_mm(h, w_out, cfg, "w_out", record)   # (E_local, cap, d)

    y = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))         # restore trash row
    out_slots = y[le_safe, pos_safe]                 # (T*k, d)
    out_slots = jnp.where(keep[:, None], out_slots, 0)
    return (out_slots.reshape(t, cfg.topk, d)
            * probs.astype(out_slots.dtype)[..., None]).sum(axis=1)


def moe_ffn(ctx: Ctx, x: jnp.ndarray, cfg: ArchConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE feed-forward. x: (B, S, d). Returns (out, aux_loss)."""
    d = x.shape[-1]
    ff = cfg.expert_ff
    gated = cfg.act in ("swiglu", "geglu")
    router_w = ctx.param("router", (d, cfg.n_experts), "float32",
                         lecun_init(), axes=("embed", None))
    wexp_axes = ("expert", "embed", "expert_mlp")
    w_in = ctx.param("w_in", (cfg.n_experts, d, ff), cfg.param_dtype,
                     lecun_init(), axes=wexp_axes)
    w_gate = (ctx.param("w_gate", (cfg.n_experts, d, ff), cfg.param_dtype,
                        lecun_init(), axes=wexp_axes) if gated else None)
    w_out = ctx.param("w_out", (cfg.n_experts, ff, d), cfg.param_dtype,
                      lecun_init(), axes=("expert", "expert_mlp", "embed"))

    record = ctx.mode == "apply"  # init traces run outside the site scopes
    sharder = current_sharder()
    use_ep = (cfg.moe_impl == "ep" and sharder is not None
              and "model" in sharder.mesh.axis_names
              and cfg.n_experts % sharder.mesh.shape["model"] == 0)
    if use_ep:
        mesh = sharder.mesh
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_size = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
        # batch and weight contraction dims must divide across the mesh
        use_ep = (x.shape[0] % dp_size == 0 and d % dp_size == 0
                  and ff % dp_size == 0)

    if not use_ep:
        return _dense_moe(x, router_w, w_in, w_gate, w_out, cfg, record)
    n_model = mesh.shape["model"]
    b, s, _ = x.shape

    wg = w_gate if gated else w_in  # placeholder operand when ungated

    def ep_body(x_loc, router_loc, w_in_loc, w_gate_loc, w_out_loc):
        # FSDP: gather the contraction dim of the expert weights just-in-time.
        def gather_d(w, axis):
            for a in dp_axes[::-1]:
                w = lax.all_gather(w, a, axis=axis, tiled=True)
            return w
        w_in_f = gather_d(w_in_loc, 1)
        w_gate_f = gather_d(w_gate_loc, 1) if gated else None
        w_out_f = gather_d(w_out_loc, 2)
        t_loc = x_loc.shape[0] * x_loc.shape[1]
        x2d = x_loc.reshape(t_loc, d)
        ids, probs, aux = _route(x2d, router_loc, cfg)
        rank = lax.axis_index("model")
        e0 = rank * (cfg.n_experts // n_model)
        out = _local_dispatch_compute(x2d, ids, probs, w_in_f, w_gate_f,
                                      w_out_f, e0, cfg, record)
        out = lax.psum(out, "model")
        aux = lax.pmean(aux, "model")
        for a in dp_axes:
            aux = lax.pmean(aux, a)
        return out.reshape(x_loc.shape), aux

    in_specs = (
        P(dp_axes if dp_axes else None, None, None),            # x
        P(None, None),                                          # router
        P("model", dp_axes if dp_axes else None, None),         # w_in
        P("model", dp_axes if dp_axes else None, None),         # w_gate
        P("model", None, dp_axes if dp_axes else None),         # w_out
    )
    out_specs = (P(dp_axes if dp_axes else None, None, None), P())
    out, aux = shard_map(
        ep_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(x, router_w, w_in, wg, w_out)
    return out, aux


def _dense_moe(x, router_w, w_in, w_gate, w_out, cfg: ArchConfig,
               record: bool = True):
    """Reference: all experts on all tokens, top-k gate-weighted. Expert
    GEMMs go through the same per-site policy as the EP path (every expert
    sees every token, so the broadcast (E, T, d) operand is the einsum's
    own working set)."""
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    ids, probs, aux = _route(x2d, router_w, cfg)
    gate_full = jnp.zeros((x2d.shape[0], cfg.n_experts), jnp.float32
                          ).at[jnp.arange(x2d.shape[0])[:, None], ids].set(probs)
    gated = cfg.act in ("swiglu", "geglu")
    xb = jnp.broadcast_to(x2d[None], (cfg.n_experts,) + x2d.shape)
    h = _expert_mm(xb, w_in, cfg, "w_in", record)                 # (E, T, f)
    g = _expert_mm(xb, w_gate, cfg, "w_gate", record) if gated else None
    h = activate(h, g, cfg.act)
    y = _expert_mm(h, w_out, cfg, "w_out", record)                # (E, T, d)
    out = jnp.einsum("etd,te->td", y.astype(jnp.float32), gate_full)
    return out.astype(x.dtype).reshape(b, s, d), aux
