"""Zamba2-style hybrid: Mamba2 (SSD) backbone + a *shared* attention block
applied every ``shared_attn_every`` Mamba blocks (arXiv:2411.15242).

Mamba2 head-structured SSD with scalar-per-head decay, state N=ssm_state.
Training lowers a time scan (chunkwise SSD is a §Perf candidate); decode
carries (conv_state, ssm_state) — O(1) per token, so long_500k runs. The
shared attention block uses a sliding window at long context (DESIGN.md §4)
with the ring-buffer KV cache from layers.py.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.parallel.sharding import constrain
from repro.parallel.unroll import unroll_for
from repro.policy import OpKind, plan_segments, site_scope

from .common import ArchConfig
from .layers import dense, embed, norm, self_attention, unembed, mlp
from .module import Ctx, apply_model, ones_init, zeros_init
from .transformer import clip_segments, scan_policy_segments, stacked_init


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def _ssd_step(state, inputs):
    """state S: (B, H, P, N). inputs: x (B,H,P), dt (B,H), B_ (B,N), C (B,N),
    a_log (H,)."""
    S, a_log = state
    x, dt, B_, C = inputs
    a = jnp.exp(-jnp.exp(a_log)[None, :] * dt)          # (B, H) decay
    S = S * a[..., None, None] + (dt[..., None] * x)[..., None] * B_[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", S, C)
    return (S, a_log), y


def mamba_block(ctx: Ctx, cfg: ArchConfig, x, *, state: Optional[dict] = None):
    """x: (B, S, d). state (decode): {'conv': (B, K-1, d_in), 'ssm': (B,H,P,N)}."""
    b, s, d = x.shape
    d_in = cfg.d_inner
    nheads = cfg.ssm_heads
    p = d_in // nheads
    n = cfg.ssm_state
    kconv = cfg.conv_kernel

    with ctx.scope("mamba"):
        y_in = norm(ctx, "ln", x, cfg)
        xz = dense(ctx, "in_proj", y_in, 2 * d_in, cfg, axes=("embed", "mlp"))
        xs, z = jnp.split(xz, 2, axis=-1)

        # causal depthwise conv over seq
        wconv = ctx.param("conv_w", (kconv, 1, d_in), cfg.param_dtype,
                          axes=("conv", None, "mlp"))
        new_conv_state = None
        if state is None:
            xpad = jnp.pad(xs, ((0, 0), (kconv - 1, 0), (0, 0)))
        else:
            xpad = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
            new_conv_state = xpad[:, -(kconv - 1):, :]
        xc = lax.conv_general_dilated(
            xpad, wconv.astype(xs.dtype), (1,), "VALID",
            dimension_numbers=("NHC", "HIO", "NHC"),
            feature_group_count=d_in)
        xc = jax.nn.silu(xc)

        # SSD projections
        bc = dense(ctx, "bc_proj", xc, 2 * n, cfg, axes=("mlp", "ssm"))
        B_, C_ = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,S,N)
        dt = dense(ctx, "dt_proj", xc, nheads, cfg, axes=("mlp", "heads"))
        dt = jax.nn.softplus(dt.astype(jnp.float32) + 1.0)       # (B,S,H)
        a_log = ctx.param("a_log", (nheads,), "float32", zeros_init(),
                          axes=("heads",))
        d_skip = ctx.param("d_skip", (nheads,), "float32", ones_init(),
                           axes=("heads",))

        xh = xc.reshape(b, s, nheads, p).astype(jnp.float32)
        if state is None:
            S0 = jnp.zeros((b, nheads, p, n), jnp.float32)
        else:
            S0 = state["ssm"]
        scan_in = (xh.transpose(1, 0, 2, 3),          # (S,B,H,P)
                   dt.transpose(1, 0, 2),              # (S,B,H)
                   B_.transpose(1, 0, 2),              # (S,B,N)
                   C_.transpose(1, 0, 2))              # (S,B,N)
        (S_fin, _), ys = lax.scan(_ssd_step, (S0, a_log), scan_in,
                                  unroll=min(unroll_for('time'), s))
        y = ys.transpose(1, 0, 2, 3)                   # (B,S,H,P)
        y = y + xh * d_skip[None, None, :, None]
        y = y.reshape(b, s, d_in).astype(x.dtype)
        y = y * jax.nn.silu(z)
        out = dense(ctx, "out_proj", y, d, cfg, axes=("mlp", "embed"))

    x = x + out
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv_state.astype(jnp.float32),
                     "ssm": S_fin}
    return x, new_state


def mamba_block_sites(i: int):
    base = f"zamba/layer_{i}/mamba"
    return [(f"{base}/{n}", OpKind.DENSE)
            for n in ("in_proj", "bc_proj", "dt_proj", "out_proj")]


def shared_attn_block(ctx: Ctx, cfg: ArchConfig, x, *, positions, cache=None):
    """The Zamba shared transformer block (params reused at every site)."""
    with ctx.scope("attn"):
        h, new_cache = self_attention(ctx, norm(ctx, "ln1", x, cfg), cfg,
                                      positions=positions, cache=cache)
    x = x + h
    with ctx.scope("ffn"):
        x = x + mlp(ctx, norm(ctx, "ln2", x, cfg), cfg)
    return constrain(x, ("act_batch", "act_seq", "act_embed")), new_cache


class ZambaModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.every = cfg.shared_attn_every
        self.n_sites = cfg.n_layers // self.every if self.every else 0
        self.segments = plan_segments(
            cfg.approx_policy, mamba_block_sites, 0, cfg.n_layers)

    def init(self, rng, *, abstract: bool = False):
        cfg = self.cfg

        def build(rng_):
            km, ka, ke = jax.random.split(rng_, 3)
            params, axes = {}, {}
            ctx = Ctx("init", rng=ke)
            embed(ctx, jnp.zeros((1, 1), jnp.int32), cfg)
            x0 = jnp.zeros((1, 1, cfg.d_model), cfg.compute_dtype)
            norm(ctx, "final_ln", x0, cfg)
            unembed(ctx, x0, cfg)
            params.update(ctx.params)
            axes.update(ctx.axes)
            mp, ma = stacked_init(
                lambda c, xx: mamba_block(c, cfg, xx), km, cfg.n_layers, x0)
            params["mamba_blocks"] = mp
            axes.update({("mamba_blocks",) + p: a for p, a in ma.items()})
            if self.n_sites:
                ctx2 = Ctx("init", rng=ka)
                shared_attn_block(ctx2, cfg, x0,
                                  positions=jnp.zeros((1,), jnp.int32))
                params["shared_attn"] = ctx2.params
                axes.update({("shared_attn",) + p: a
                             for p, a in ctx2.axes.items()})
            return params, axes

        if abstract:
            holder = {}

            def f(r):
                p, a = build(r)
                holder.update(a)
                return p

            return jax.eval_shape(f, rng), holder
        return build(rng)

    def _mamba_fn(self):
        cfg = self.cfg

        def fn(c, xx, cache=None):
            xx, st = mamba_block(c, cfg, xx, state=cache)
            return xx, st, jnp.zeros((), jnp.float32)

        return fn

    def forward(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        positions = jnp.arange(tokens.shape[1])
        ctx = Ctx("apply", params=params)
        fn = self._mamba_fn()
        mp = params["mamba_blocks"]
        with site_scope("zamba"):
            x = embed(ctx, tokens, cfg)
            if not self.n_sites:
                x, _, _ = scan_policy_segments(fn, mp, x,
                                               segments=self.segments,
                                               remat=cfg.remat)
            else:
                for site in range(self.n_sites):
                    lo, hi = site * self.every, (site + 1) * self.every
                    x, _, _ = scan_policy_segments(
                        fn, mp, x,
                        segments=clip_segments(self.segments, lo, hi),
                        remat=cfg.remat)
                    with site_scope(f"shared_{site}"):
                        x, _ = apply_model(
                            lambda c, xx: shared_attn_block(
                                c, cfg, xx, positions=positions),
                            params["shared_attn"], x)
                # tail blocks beyond the last shared-attn site (38 = 6x6 + 2)
                tail0 = self.n_sites * self.every
                if tail0 < cfg.n_layers:
                    x, _, _ = scan_policy_segments(
                        fn, mp, x,
                        segments=clip_segments(self.segments, tail0,
                                               cfg.n_layers),
                        remat=cfg.remat)
            x = norm(ctx, "final_ln", x, cfg)
            logits = unembed(ctx, x, cfg)
        return logits, jnp.zeros((), jnp.float32)

    def init_cache(self, batch_size: int, max_seq: int, *,
                   abstract: bool = False):
        cfg = self.cfg
        p = cfg.d_inner // cfg.ssm_heads
        ring = bool(cfg.window) and cfg.window < max_seq
        size = min(cfg.window, max_seq) if ring else max_seq

        def mk(shape, dtype=jnp.float32, fill=0):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dtype)
            return jnp.full(shape, fill, dtype)

        cache = {
            "conv": mk((cfg.n_layers, batch_size, cfg.conv_kernel - 1,
                        cfg.d_inner)),
            "ssm": mk((cfg.n_layers, batch_size, cfg.ssm_heads, p,
                       cfg.ssm_state)),
            "pos": mk((), jnp.int32),
        }
        dt = jnp.dtype(cfg.compute_dtype)
        for site in range(self.n_sites):
            c = {
                "k": mk((batch_size, size, cfg.kv_heads, cfg.head_dim), dt),
                "v": mk((batch_size, size, cfg.kv_heads, cfg.head_dim), dt),
            }
            if ring:
                c["abs_pos"] = mk((size,), jnp.int32, fill=-1)
            cache[f"attn_{site}"] = c
        return cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        pos = cache["pos"]
        positions = jnp.reshape(pos, (1,))
        ctx = Ctx("apply", params=params)
        fn = self._mamba_fn()
        mp = params["mamba_blocks"]
        mamba_state = {"conv": cache["conv"], "ssm": cache["ssm"]}
        new_cache = dict(cache)
        with site_scope("zamba"):
            x = embed(ctx, tokens, cfg)
            if not self.n_sites:
                x, ns, _ = scan_policy_segments(fn, mp, x,
                                                segments=self.segments,
                                                cache=mamba_state)
                new_cache.update(ns)
            else:
                parts = []
                for site in range(self.n_sites):
                    lo, hi = site * self.every, (site + 1) * self.every
                    x, ns, _ = scan_policy_segments(
                        fn, mp, x,
                        segments=clip_segments(self.segments, lo, hi),
                        cache=mamba_state)
                    parts.append(ns)
                    ac = dict(cache[f"attn_{site}"], pos=pos)
                    with site_scope(f"shared_{site}"):
                        x, nac = apply_model(
                            lambda c, xx: shared_attn_block(
                                c, cfg, xx, positions=positions, cache=ac),
                            params["shared_attn"], x)
                    nac.pop("pos")
                    new_cache[f"attn_{site}"] = nac
                # tail blocks (38 = 6x6 + 2)
                tail0 = self.n_sites * self.every
                if tail0 < cfg.n_layers:
                    x, ns, _ = scan_policy_segments(
                        fn, mp, x,
                        segments=clip_segments(self.segments, tail0,
                                               cfg.n_layers),
                        cache=mamba_state)
                    parts.append(ns)
                merged = jax.tree.map(lambda *t: jnp.concatenate(t, 0),
                                      *parts)
                new_cache.update(merged)
            x = norm(ctx, "final_ln", x, cfg)
            logits = unembed(ctx, x, cfg)
        new_cache["pos"] = pos + 1
        return logits, new_cache
