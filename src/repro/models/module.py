"""Micro-module system: explicit param pytrees with logical sharding axes.

No flax in this container, and a framework wants explicit state anyway
(MaxText-style): a model is a pure function ``fn(ctx, *args) -> out`` that
declares parameters through ``ctx.param(...)``. Three contexts:

  * ``init``  — create parameters (returns the params pytree);
  * ``apply`` — read parameters from an existing pytree;
  * the logical sharding axes for every parameter are recorded at declaration
    time and retrievable as a matching pytree (``axes_of``).

Layer stacks are declared as *stacked* parameters (leading ``layers`` axis)
and consumed with ``jax.lax.scan`` — this keeps HLO size O(1) in depth, which
matters at 96 layers / 512 devices.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.policy.sites import site_scope

Params = Dict[str, Any]
Axes = Tuple[Optional[str], ...]


# ---------------------------------------------------------------------------
# Initializers (match common LM practice)
# ---------------------------------------------------------------------------

def normal_init(stddev: float = 0.02):
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return init


def lecun_init():
    def init(key, shape, dtype):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    return init


def he_init():
    """Kaiming/He init (gain 2 for ReLU): required to train deep plain-ReLU
    stacks like VGG-16 without normalization layers. For conv kernels
    (kh, kw, cin, cout) fan_in = kh*kw*cin."""
    def init(key, shape, dtype):
        fan_in = int(np.prod(shape[:-1])) if len(shape) >= 2 else shape[-1]
        std = np.sqrt(2.0 / fan_in)
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    return init


def zeros_init():
    def init(key, shape, dtype):
        return jnp.zeros(shape, dtype)
    return init


def ones_init():
    def init(key, shape, dtype):
        return jnp.ones(shape, dtype)
    return init


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------

class Ctx:
    """Parameter declaration/lookup context.

    mode='init': creates params (optionally abstractly under eval_shape).
    mode='apply': reads them from the provided tree.
    Axes are recorded in both modes into ``axes`` (a flat dict path->axes).
    """

    def __init__(self, mode: str, params: Optional[Params] = None,
                 rng: Optional[jax.Array] = None):
        assert mode in ("init", "apply")
        self.mode = mode
        self.params: Params = params if params is not None else {}
        self.rng = rng
        self._path: list = []
        self.axes: Dict[Tuple[str, ...], Axes] = {}
        self._counter = 0

    @contextlib.contextmanager
    def scope(self, name: str):
        """Parameter scope; also mirrored onto the op-site path stack so
        site paths (repro.policy.sites) track parameter paths."""
        self._path.append(name)
        try:
            with site_scope(name):
                yield self
        finally:
            self._path.pop()

    def _subtree(self, create: bool) -> Params:
        t = self.params
        for p in self._path:
            if p not in t:
                if not create:
                    raise KeyError(f"missing param scope {'/'.join(self._path)}")
                t[p] = {}
            t = t[p]
        return t

    def _fold_key(self) -> jax.Array:
        self._counter += 1
        return jax.random.fold_in(self.rng, self._counter)

    def param(self, name: str, shape: Sequence[int], dtype,
              init: Callable = None, axes: Axes = None) -> jnp.ndarray:
        path = tuple(self._path) + (name,)
        if axes is not None and len(axes) != len(shape):
            raise ValueError(f"{path}: axes {axes} rank != shape {shape}")
        self.axes[path] = axes if axes is not None else (None,) * len(shape)
        if self.mode == "init":
            t = self._subtree(create=True)
            if name not in t:
                init = init or normal_init()
                t[name] = init(self._fold_key(), tuple(shape), dtype)
            return t[name]
        t = self._subtree(create=False)
        if name not in t:
            raise KeyError(f"missing param {'/'.join(path)}")
        return t[name]


def init_model(fn: Callable, rng: jax.Array, *args, abstract: bool = False, **kw):
    """Run ``fn`` in init mode. Returns (params, axes_by_path).

    abstract=True runs under eval_shape (no allocation) — used by the dry-run
    to build parameter ShapeDtypeStructs for 340B-scale models.
    """
    if abstract:
        holder = {}

        def shaped(rng_):
            ctx = Ctx("init", rng=rng_)
            fn(ctx, *args, **kw)
            holder["axes"] = ctx.axes
            return ctx.params

        params = jax.eval_shape(shaped, rng)
        return params, holder["axes"]
    ctx = Ctx("init", rng=rng)
    fn(ctx, *args, **kw)
    return ctx.params, ctx.axes


def apply_model(fn: Callable, params: Params, *args, **kw):
    ctx = Ctx("apply", params=params)
    return fn(ctx, *args, **kw)


def axes_tree(params: Params, axes: Dict[Tuple[str, ...], Axes]) -> Params:
    """Build a pytree of logical-axes tuples congruent with ``params``."""

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return axes[path]

    return walk(params, ())
