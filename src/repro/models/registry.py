"""Model registry: family -> model class, plus the shared LM loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .cnn import CNNModel
from .common import ArchConfig
from .transformer import DecoderLM, EncDecLM
from .xlstm import XLSTMModel
from .zamba import ZambaModel

_FAMILIES = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "vlm": DecoderLM,
    "audio": EncDecLM,
    "ssm": XLSTMModel,
    "hybrid": ZambaModel,
    "cnn": CNNModel,
}


def build_model(cfg: ArchConfig):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(
            f"unknown family {cfg.family!r} for arch {cfg.name}") from None
    return cls(cfg)


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            aux: jnp.ndarray = 0.0, aux_weight: float = 0.01) -> jnp.ndarray:
    """Next-token cross entropy in f32 (+ MoE load-balance aux)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux_weight * aux


def classifier_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
