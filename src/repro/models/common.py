"""Architecture configuration shared by the whole model zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.config import Backend, DaismConfig, Variant
from repro.policy import ApproxPolicy, parse_policy, validate_for_dtype

EXACT = DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (frozen+hashable => usable as a jit static)."""

    name: str
    family: str               # dense | moe | vlm | ssm | audio | hybrid | cnn
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"       # swiglu | geglu | relu2 | gelu
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # --- MoE ---
    n_experts: int = 0
    topk: int = 0
    expert_ff: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "ep"      # ep (shard_map) | dense (reference)
    # --- VLM ---
    cross_every: int = 0      # a cross-attn block after every N self blocks
    n_image_tokens: int = 0
    # --- SSM / hybrid / xLSTM ---
    ssm_state: int = 0
    d_inner: int = 0
    ssm_heads: int = 0
    conv_kernel: int = 4
    shared_attn_every: int = 0   # zamba2: shared attn block cadence
    slstm_every: int = 0         # xlstm: 1 sLSTM per N blocks
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 0
    # --- attention ---
    window: int = 0           # sliding window; 0 = full causal
    attn_chunk: int = 1024    # online-softmax KV chunk length
    # --- numerics / execution ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_score_dtype: str = "float32"   # bfloat16 halves attention traffic
    rnn_state_dtype: str = "float32"
    # DEPRECATED: one global config for every GEMM. Kept as a shim — when
    # ``policy`` is unset it is wrapped into a uniform one-rule policy by
    # ``approx_policy``. New code should set ``policy`` instead.
    daism: DaismConfig = EXACT
    # Per-site approximation policy (repro.policy). Takes precedence over
    # ``daism`` when set.
    policy: Optional[ApproxPolicy] = None
    remat: str = "none"       # none | dots | full
    scan_layers: bool = True

    def __post_init__(self) -> None:
        # fail at construction, not deep inside a kernel trace: every config
        # a site can resolve to must be runnable on the compute dtype
        for where, dcfg in self._numerics_configs():
            validate_for_dtype(dcfg, self.compute_dtype, site=where)

    def _numerics_configs(self):
        if self.policy is not None:
            for r in self.policy.rules:
                yield f"{self.name}:policy[{r.pattern}]", r.config
            yield f"{self.name}:policy[default]", self.policy.default
        else:
            yield f"{self.name}:daism", self.daism

    @property
    def approx_policy(self) -> ApproxPolicy:
        """The effective policy: ``policy`` if set, else the deprecation shim
        wrapping the legacy ``daism`` field as a uniform one-rule policy."""
        if self.policy is not None:
            return self.policy
        return ApproxPolicy.uniform(self.daism)

    def with_policy(self, policy) -> "ArchConfig":
        """Return a copy using ``policy`` (an ApproxPolicy or a spec string
        like ``"*/attn/*=exact,*=pc3_tr"``)."""
        if isinstance(policy, str):
            policy = parse_policy(policy)
        return dataclasses.replace(self, policy=policy)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    def smoke(self, **overrides) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        small = dict(
            n_layers=max(2, min(self.n_layers, 2 + (self.shared_attn_every > 0))),
            d_model=64,
            n_heads=4,
            kv_heads=max(1, min(self.kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            topk=min(self.topk, 2) if self.topk else 0,
            expert_ff=64 if self.expert_ff else 0,
            d_inner=128 if self.d_inner else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            cross_every=2 if self.cross_every else 0,
            n_image_tokens=8 if self.n_image_tokens else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_frames=16 if self.enc_frames else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            window=min(self.window, 32) if self.window else 0,
            attn_chunk=16,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
