"""xLSTM (sLSTM + mLSTM blocks) — attention-free LM (arXiv:2405.04517).

Faithful cell equations with exponential gating + max-stabilizer state.
Training uses a time scan (the chunkwise-parallel mLSTM form is a §Perf
candidate, recorded in EXPERIMENTS.md); decode is O(1) per token with
matrix-memory state — which is why this arch *runs* the long_500k shape.

DAISM applicability: all projections (q/k/v/o, up/down) route through
``dense`` and therefore the approximate GEMM; the recurrences themselves are
elementwise (no stationary operand) and stay exact — DESIGN.md §4.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import constrain
from repro.parallel.unroll import unroll_for
from repro.policy import OpKind, plan_segments, site_scope

from .common import ArchConfig
from .layers import dense, norm, unembed, embed
from .module import Ctx, apply_model, init_model
from .transformer import clip_segments, scan_policy_segments, stacked_init


# ---------------------------------------------------------------------------
# mLSTM: matrix memory C (hd x hd) per head, exponential gating
# ---------------------------------------------------------------------------

def _mlstm_step(state, inputs):
    """state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)); inputs per timestep."""
    C, n, m = state
    sd = C.dtype
    q, k, v, i_pre, f_pre = inputs  # q,k,v: (B,H,hd); gates: (B,H)
    m_new = jnp.maximum(f_pre + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)[..., None].astype(sd)
    f_g = jnp.exp(f_pre + m - m_new)[..., None].astype(sd)
    ks_ = k.astype(sd)
    C = f_g[..., None] * C + i_g[..., None] * (
        v.astype(sd)[..., :, None] * ks_[..., None, :])
    n = f_g * n + i_g * ks_
    num = jnp.einsum("bhij,bhj->bhi", C, q.astype(sd),
                     preferred_element_type=jnp.float32)
    den = jnp.maximum(jnp.abs(jnp.einsum(
        "bhj,bhj->bh", n, q.astype(sd),
        preferred_element_type=jnp.float32)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_cell(ctx: Ctx, x: jnp.ndarray, cfg: ArchConfig, state=None):
    """x: (B, S, d). Returns (y (B, S, d), new_state)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    q = dense(ctx, "wq", x, d, cfg, axes=("embed", "heads"))
    k = dense(ctx, "wk", x, d, cfg, axes=("embed", "heads")) / jnp.sqrt(
        jnp.asarray(hd, x.dtype))
    v = dense(ctx, "wv", x, d, cfg, axes=("embed", "heads"))
    gates = dense(ctx, "wgate", x, 3 * nh, cfg, axes=("embed", "heads"))
    i_pre, f_pre, o_pre = jnp.split(gates.astype(jnp.float32), 3, axis=-1)
    f_pre = f_pre + 1.0  # forget-gate bias toward remembering

    def heads(t):  # (B, S, d) -> (S, B, H, hd) scan-major
        return t.reshape(b, s, nh, hd).transpose(1, 0, 2, 3).astype(jnp.float32)

    qs, ks, vs = heads(q), heads(k), heads(v)
    ig = i_pre.reshape(b, s, nh).transpose(1, 0, 2)
    fg = f_pre.reshape(b, s, nh).transpose(1, 0, 2)

    sd = jnp.dtype(cfg.rnn_state_dtype)
    if state is None:
        state = (jnp.zeros((b, nh, hd, hd), sd),
                 jnp.zeros((b, nh, hd), sd),
                 jnp.full((b, nh), -jnp.inf, jnp.float32))
    else:
        state = (state[0].astype(sd), state[1].astype(sd), state[2])
    state, hs = lax.scan(_mlstm_step, state, (qs, ks, vs, ig, fg),
                         unroll=min(unroll_for('time'), s))
    state = (state[0].astype(jnp.float32), state[1].astype(jnp.float32),
             state[2])
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d)
    h = h * jax.nn.sigmoid(o_pre.reshape(b, s, nh)).repeat(hd, axis=-1)
    y = dense(ctx, "wo", h.astype(x.dtype), d, cfg, axes=("heads", "embed"))
    return y, state


# ---------------------------------------------------------------------------
# sLSTM: scalar memory per unit, exponential gating, block-diag recurrence
# ---------------------------------------------------------------------------

def _slstm_step(state, inputs, r_z, r_i, r_f, r_o, nh, hd):
    c, n, m, h_prev = state
    z_x, i_x, f_x, o_x = inputs  # (B, H, hd) pre-activations from input

    def rec(r, hp):  # block-diagonal recurrent matmul per head
        return jnp.einsum("bhi,hij->bhj", hp, r)

    z = jnp.tanh(z_x + rec(r_z, h_prev))
    i_pre = i_x + rec(r_i, h_prev)
    f_pre = f_x + rec(r_f, h_prev) + 1.0
    o = jax.nn.sigmoid(o_x + rec(r_o, h_prev))
    m_new = jnp.maximum(f_pre + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_pre + m - m_new)
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h), h


def slstm_cell(ctx: Ctx, x: jnp.ndarray, cfg: ArchConfig, state=None):
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    zx = dense(ctx, "wz", x, d, cfg, axes=("embed", "heads"))
    ix = dense(ctx, "wi", x, d, cfg, axes=("embed", "heads"))
    fx = dense(ctx, "wf", x, d, cfg, axes=("embed", "heads"))
    ox = dense(ctx, "wo_in", x, d, cfg, axes=("embed", "heads"))
    rs = {nm: ctx.param(f"r_{nm}", (nh, hd, hd), "float32",
                        axes=("heads", None, None))
          for nm in ("z", "i", "f", "o")}

    def to_sbh(t):
        return t.reshape(b, s, nh, hd).transpose(1, 0, 2, 3).astype(jnp.float32)

    if state is None:
        z0 = jnp.zeros((b, nh, hd), jnp.float32)
        state = (z0, z0, jnp.full((b, nh, hd), -jnp.inf, jnp.float32), z0)
    step = functools.partial(_slstm_step, r_z=rs["z"], r_i=rs["i"],
                             r_f=rs["f"], r_o=rs["o"], nh=nh, hd=hd)
    state, hs = lax.scan(lambda st, ins: step(st, ins), state,
                         (to_sbh(zx), to_sbh(ix), to_sbh(fx), to_sbh(ox)),
                         unroll=min(unroll_for('time_s'), s))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = dense(ctx, "w_down", h, d, cfg, axes=("heads", "embed"))
    return y, state


# ---------------------------------------------------------------------------
# Blocks + model
# ---------------------------------------------------------------------------

def xlstm_block(ctx: Ctx, cfg: ArchConfig, x, *, kind: str, state=None):
    cell = mlstm_cell if kind == "mlstm" else slstm_cell
    with ctx.scope(kind):
        h, new_state = cell(ctx, norm(ctx, "ln", x, cfg), cfg, state=state)
    x = x + h
    return constrain(x, ("act_batch", "act_seq", "act_embed")), new_state


_CELL_SITES = {
    "mlstm": ("wq", "wk", "wv", "wgate", "wo"),
    "slstm": ("wz", "wi", "wf", "wo_in", "w_down"),
}


def xlstm_block_sites(kinds, i: int):
    kind = kinds[i]
    return [(f"xlstm/layer_{i}/{kind}/{n}", OpKind.DENSE)
            for n in _CELL_SITES[kind]]


class XLSTMModel:
    """Blocks: 1 sLSTM per ``slstm_every`` blocks (xLSTM[7:1] for 1.3b),
    mLSTM otherwise. Two stacked scans keep HLO compact."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        every = cfg.slstm_every or (cfg.n_layers + 1)
        self.kinds = ["slstm" if (i + 1) % every == 0 else "mlstm"
                      for i in range(cfg.n_layers)]
        self.n_m = self.kinds.count("mlstm")
        self.n_s = self.kinds.count("slstm")
        self.segments = plan_segments(
            cfg.approx_policy,
            functools.partial(xlstm_block_sites, self.kinds),
            0, cfg.n_layers)

    def init(self, rng, *, abstract: bool = False):
        cfg = self.cfg

        def build(rng_):
            km, ks, ke = jax.random.split(rng_, 3)
            params, axes = {}, {}
            ctx = Ctx("init", rng=ke)
            embed(ctx, jnp.zeros((1, 1), jnp.int32), cfg)
            x0 = jnp.zeros((1, 1, cfg.d_model), cfg.compute_dtype)
            norm(ctx, "final_ln", x0, cfg)
            unembed(ctx, x0, cfg)
            params.update(ctx.params)
            axes.update(ctx.axes)
            mp, ma = stacked_init(
                lambda c, xx: xlstm_block(c, cfg, xx, kind="mlstm"),
                km, max(self.n_m, 1), x0)
            params["mlstm_blocks"] = mp
            axes.update({("mlstm_blocks",) + p: a for p, a in ma.items()})
            if self.n_s:
                sp, sa = stacked_init(
                    lambda c, xx: xlstm_block(c, cfg, xx, kind="slstm"),
                    ks, self.n_s, x0)
                params["slstm_blocks"] = sp
                axes.update({("slstm_blocks",) + p: a for p, a in sa.items()})
            return params, axes

        if abstract:
            holder = {}

            def f(r):
                p, a = build(r)
                holder.update(a)
                return p

            return jax.eval_shape(f, rng), holder
        return build(rng)

    def _run(self, params, x, states=None):
        """Apply blocks in kind order; states: dict of stacked states or None."""
        cfg = self.cfg
        new_m, new_s = None, None

        def m_fn(c, xx, cache=None):
            xx, st = xlstm_block(c, cfg, xx, kind="mlstm", state=cache)
            return xx, st, jnp.zeros((), jnp.float32)

        def s_fn(c, xx, cache=None):
            xx, st = xlstm_block(c, cfg, xx, kind="slstm", state=cache)
            return xx, st, jnp.zeros((), jnp.float32)

        # homogeneous interleave: run contiguous mlstm groups then the slstm
        mp, sp = params["mlstm_blocks"], params.get("slstm_blocks")
        every = cfg.slstm_every or (cfg.n_layers + 1)
        group = every - 1  # mlstm blocks per slstm
        mi, si = 0, 0
        new_m_parts, new_s_parts = [], []
        i = 0
        while i < cfg.n_layers:
            n_m_here = min(group if self.n_s else cfg.n_layers,
                           self.n_m - mi)
            if n_m_here > 0:
                # mlstm stack rows [mi, mi+n) are global layers [i, i+n):
                # policy segments are global, so slice relative to base
                subc = (None if states is None else states["mlstm"])
                with site_scope("xlstm"):
                    x, nc, _ = scan_policy_segments(
                        m_fn, mp, x,
                        segments=clip_segments(self.segments, i,
                                               i + n_m_here),
                        base=i - mi, cache=subc,
                        remat=cfg.remat if states is None else "none")
                if nc is not None:
                    new_m_parts.append(nc)
                mi += n_m_here
                i += n_m_here
            if self.n_s and si < self.n_s and i < cfg.n_layers:
                pslice = jax.tree.map(lambda p: p[si], sp)
                st = (None if states is None else jax.tree.map(
                    lambda t: t[si], states["slstm"]))
                with site_scope("xlstm"), site_scope(f"layer_{i}"):
                    x, nst = apply_model(
                        lambda c, xx: xlstm_block(c, cfg, xx, kind="slstm",
                                                  state=st), pslice, x)
                new_s_parts.append(nst)
                si += 1
                i += 1
        new_states = None
        if states is not None:
            new_states = {
                "mlstm": jax.tree.map(lambda *t: jnp.concatenate(t, 0),
                                      *new_m_parts),
            }
            if new_s_parts:
                new_states["slstm"] = jax.tree.map(
                    lambda *t: jnp.stack(t, 0), *new_s_parts)
        return x, new_states

    def forward(self, params, batch):
        ctx = Ctx("apply", params=params)
        x = embed(ctx, batch["tokens"], self.cfg)
        x, _ = self._run(params, x)
        x = norm(ctx, "final_ln", x, self.cfg)
        with site_scope("xlstm"):
            logits = unembed(ctx, x, self.cfg)
        return logits, jnp.zeros((), jnp.float32)

    def init_cache(self, batch_size: int, max_seq: int, *,
                   abstract: bool = False):
        cfg = self.cfg
        nh = cfg.n_heads
        hd = cfg.d_model // nh

        def mk(shape, fill=0.0):
            if abstract:
                return jax.ShapeDtypeStruct(shape, jnp.float32)
            return jnp.full(shape, fill, jnp.float32)

        cache = {
            "mlstm": (mk((self.n_m, batch_size, nh, hd, hd)),
                      mk((self.n_m, batch_size, nh, hd)),
                      mk((self.n_m, batch_size, nh), -jnp.inf if not abstract
                         else 0.0)),
            "pos": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                    else jnp.zeros((), jnp.int32)),
        }
        if self.n_s:
            z = mk((self.n_s, batch_size, nh, hd))
            cache["slstm"] = (z, z, mk((self.n_s, batch_size, nh, hd),
                                       -jnp.inf if not abstract else 0.0), z)
        return cache

    def decode_step(self, params, tokens, cache):
        ctx = Ctx("apply", params=params)
        x = embed(ctx, tokens, self.cfg)
        states = {k: v for k, v in cache.items() if k != "pos"}
        x, new_states = self._run(params, x, states=states)
        x = norm(ctx, "final_ln", x, self.cfg)
        with site_scope("xlstm"):
            logits = unembed(ctx, x, self.cfg)
        new_states["pos"] = cache["pos"] + 1
        return logits, new_states
