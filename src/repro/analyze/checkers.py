"""Pluggable checkers over a :class:`~repro.analyze.sitegraph.SiteGraph`.

Each checker is a pure function ``SiteGraph -> [Finding]``. Codes are
stable (the README troubleshooting table maps each to its fix):

=======  ========  ====================================================
code     severity  meaning
=======  ========  ====================================================
POL001   error     policy rule matches zero op-sites
POL002   warning   rule fully shadowed by earlier rules
POL003   warning   catch-all rule ordered before more-specific rules
POL004   warning   deprecated ``ArchConfig.daism`` uniform shim in use
BCK001   error     backend illegal for the site's operand dtype
TIL001   warning   GEMM dims not divisible by Pallas block sizes
TIL002   warning   per-kernel VMEM footprint exceeds the budget
TIL003   info      Pallas sites auto-select interpret mode here
TIL004   warning   flash-attention tiles pad the sequence / misalign lanes
TIL005   error     flash-attention DAISM variant on a non-bf16 model
RCP001   warning   policy shatters a scanned stack into many segments
RCP002   warning   dispatcher cache would hold many kernel variants
ENE001   info      estimated multiply-energy summary
SRV000   error     EngineConfig rejected at construction
SRV001   error*    model ``window`` incompatible with the paged cache
SRV002   error*    KV pool cannot hold one max-length request
SRV003   warning   KV pool oversubscribed vs expected concurrency
SRV004   warning   two tiers resolve to the same policy group
SRV005   error*    tier policy spec invalid for this model
SRV006   info      model has no paged decode path; serving checks skipped
SRV007   error*    KV pages / decode rows not divisible by mesh shards
SRV008   warning   swap buffer smaller than one max-length request
SRV009   error*    speculative draft policy incompatible with the target
=======  ========  ====================================================

``error*`` codes downgrade to warnings in *advisory* mode (the ``--all``
CI sweep, where no serving deployment is actually requested).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax

from repro.core.config import Backend
from repro.policy import (OpKind, auto_interpret, describe_config,
                          parse_policy, validate_for_dtype)

from .sitegraph import SiteGraph

SEVERITIES = ("error", "warning", "info")
CATEGORIES = ("policy", "backend", "tiling", "recompile", "energy", "serving")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer diagnostic, anchored to a site/rule where possible."""

    code: str
    severity: str      # error | warning | info
    category: str      # see CATEGORIES
    message: str
    site: str = ""     # site path or rule/tier anchor ("" = whole config)

    def __str__(self) -> str:
        where = f" [{self.site}]" if self.site else ""
        return f"{self.code} {self.severity}: {self.message}{where}"


def check_policy(graph: SiteGraph) -> List[Finding]:
    """Rule reachability: zero-match, shadowing, catch-all ordering, and the
    deprecated ``daism`` shim."""
    findings = []
    policy = graph.policy
    site_keys = [(s.path, s.kind) for s in graph.sites]
    n_rules = len(policy.rules)
    matched = [set() for _ in range(n_rules)]  # sites the pattern matches
    won = [set() for _ in range(n_rules)]      # sites the rule resolves
    for path, kind in site_keys:
        winner = None
        for i, rule in enumerate(policy.rules):
            if rule.matches(path, kind):
                matched[i].add((path, kind))
                if winner is None:
                    winner = i
        if winner is not None:
            won[winner].add((path, kind))
    for i, rule in enumerate(policy.rules):
        anchor = f"rule {i}: {rule.pattern}"
        if not matched[i]:
            findings.append(Finding(
                "POL001", "error", "policy",
                f"rule {i} ({rule.pattern}={describe_config(rule.config)}) "
                f"matches none of the model's {len(site_keys)} op-sites — "
                "it silently does nothing; fix the glob or delete the rule",
                site=anchor))
        elif not won[i]:
            shadows = sorted({j for j in range(i)
                              for s in matched[i] if s in matched[j]})
            by = ", ".join(f"rule {j} ({policy.rules[j].pattern})"
                           for j in shadows[:3])
            findings.append(Finding(
                "POL002", "warning", "policy",
                f"rule {i} ({rule.pattern}={describe_config(rule.config)}) "
                f"is fully shadowed by {by}: every site it matches is "
                "claimed earlier (first match wins); reorder or remove it",
                site=anchor))
        if matched[i] and len(matched[i]) == len(site_keys) and i < n_rules - 1:
            findings.append(Finding(
                "POL003", "warning", "policy",
                f"rule {i} ({rule.pattern}) is a catch-all placed before "
                f"{n_rules - 1 - i} more-specific rule(s), which can never "
                "fire; move the catch-all last (or use default=)",
                site=anchor))
    if graph.cfg.policy is None and not graph.cfg.daism.exact:
        findings.append(Finding(
            "POL004", "warning", "policy",
            "config uses the deprecated ArchConfig.daism uniform shim "
            f"(daism={describe_config(graph.cfg.daism)}); set "
            f"policy=parse_policy('*={describe_config(graph.cfg.daism)}') "
            "instead"))
    return findings


def check_backend(graph: SiteGraph) -> List[Finding]:
    """Backend legality per site, ahead of any trace: the exact errors
    ``resolve_site`` would raise mid-jit, reported as findings."""
    findings = []
    for s in graph.sites:
        try:
            validate_for_dtype(s.config, s.dtype, site=s.path)
        except ValueError as e:
            findings.append(Finding("BCK001", "error", "backend", str(e),
                                    site=s.path))
    return findings


# VMEM bytes per kernel grid step (see kernels/daism_matmul.py docstring):
# the fused shift-plane sweep keeps ~3 live (bm, K_FUSE, bn) slab temporaries
# (K-independent) + the resident f32 out tile, plus the streamed bf16 a/w
# tiles — block_k only enters through the streamed tiles now.
def _vmem_bytes(bm: int, bk: int, bn: int) -> int:
    from repro.kernels.approx_product import K_FUSE

    return ((3 * bm * min(bk, K_FUSE) * bn + bm * bn) * 4
            + (bm * bk + bk * bn) * 2)


def check_tiling(graph: SiteGraph, *,
                 vmem_budget_mib: float = 16.0) -> List[Finding]:
    """Pallas tiling sanity: padding waste and VMEM footprint estimates."""
    findings = []
    interp_sites = []
    for s in graph.sites:
        if s.config.exact or s.config.backend is not Backend.PALLAS:
            continue
        m, k, n = s.dims
        c = s.config
        pad = {"m": (m, c.block_m), "k": (k, c.block_k), "n": (n, c.block_n)}
        ragged = {ax: (dim, blk) for ax, (dim, blk) in pad.items()
                  if dim % blk}
        if ragged:
            padded = [f"{ax}: {dim} -> {-(-dim // blk) * blk}"
                      for ax, (dim, blk) in ragged.items()]
            findings.append(Finding(
                "TIL001", "warning", "tiling",
                f"GEMM dims (m={m}, k={k}, n={n}) not divisible by Pallas "
                f"blocks (bm={c.block_m}, bk={c.block_k}, bn={c.block_n}); "
                f"the kernel pads {', '.join(padded)} — wasted compute and "
                "an extra compiled shape",
                site=s.path))
        vmem = _vmem_bytes(c.block_m, c.block_k, c.block_n)
        if vmem > vmem_budget_mib * (1 << 20):
            findings.append(Finding(
                "TIL002", "warning", "tiling",
                f"estimated per-kernel VMEM footprint {vmem / (1 << 20):.1f} "
                f"MiB exceeds the {vmem_budget_mib:.0f} MiB budget "
                f"(bm={c.block_m}, bk={c.block_k}, bn={c.block_n}); shrink "
                "the block sizes",
                site=s.path))
        if s.config.interpret is None and auto_interpret(s.config):
            interp_sites.append(s.path)
    if interp_sites:
        findings.append(Finding(
            "TIL003", "info", "tiling",
            f"{len(interp_sites)} Pallas site(s) will auto-select "
            f"interpret mode on this host (backend={jax.default_backend()}) "
            "— orders of magnitude slower than compiled; use backend 'jnp' "
            "for CPU runs",
            site=interp_sites[0]))
    return findings


def check_attention(graph: SiteGraph) -> List[Finding]:
    """Flash-attention dispatch legality (TIL family, ATTN_QK sites only).

    TIL004: the flash kernel tiles (block_q, block_k) = (128, 128) over the
    sequence and keeps the head dim whole in VMEM lanes — ragged sequence
    lengths pad (masked but wasted compute), and a head dim off the 128-lane
    width underutilizes the VPU. TIL005: an approximate variant through the
    flash kernel is bfloat16-only (mirrors the ``resolve_site`` error as a
    pre-trace finding).
    """
    from repro.kernels.flash_attention import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q

    findings = []
    for s in graph.sites:
        if s.kind is not OpKind.ATTN_QK or s.config.attn_kernel != "flash":
            continue
        sq, d, skv = s.dims
        if not s.config.exact and s.dtype != "bfloat16":
            findings.append(Finding(
                "TIL005", "error", "tiling",
                f"flash attention with DAISM variant "
                f"'{s.config.variant.value}' is bfloat16-only but the site "
                f"computes in {s.dtype}; run the site exact (keep ':flash', "
                "drop the variant) or switch the compute dtype",
                site=s.path))
        ragged = [f"{ax}: {dim} -> {-(-dim // blk) * blk}"
                  for ax, dim, blk in (("sq", sq, DEFAULT_BLOCK_Q),
                                       ("skv", skv, DEFAULT_BLOCK_K))
                  if dim % blk]
        if d % 128:
            ragged.append(f"head_dim {d} off the 128-lane width")
        if ragged:
            findings.append(Finding(
                "TIL004", "warning", "tiling",
                f"flash-attention tiles (bq={DEFAULT_BLOCK_Q}, "
                f"bk={DEFAULT_BLOCK_K}) pad this site: "
                f"{', '.join(ragged)} — masked but wasted compute on every "
                "padded tile",
                site=s.path))
    return findings


def check_recompile(graph: SiteGraph, *, max_segments: int = 4,
                    max_kernel_variants: int = 8) -> List[Finding]:
    """Recompile hazards: segment shatter and kernel-cache pressure."""
    findings = []
    for stack, segs in graph.segments.items():
        if len(segs) > max_segments:
            findings.append(Finding(
                "RCP001", "warning", "recompile",
                f"policy splits the scanned stack '{stack}' into "
                f"{len(segs)} uniform segments (> {max_segments}): each is "
                "a separate lax.scan trace, so HLO size and compile time "
                "grow with the rule granularity; coarsen the per-depth "
                "rules",
                site=stack))
    variants = {s.config for s in graph.sites if not s.config.exact}
    if len(variants) > max_kernel_variants:
        findings.append(Finding(
            "RCP002", "warning", "recompile",
            f"policy resolves {len(variants)} distinct non-exact "
            f"DaismConfigs (> {max_kernel_variants}): the dispatcher "
            "kernel cache compiles one kernel per (config, shape) pair; "
            "merge near-identical configs"))
    return findings


def check_energy(graph: SiteGraph) -> List[Finding]:
    """Always-on summary so the energy math is visible in every report."""
    used, exact = graph.energy_uj()
    if exact <= 0:
        return [Finding("ENE001", "info", "energy",
                        "no contraction sites traced; energy model idle")]
    saved = 100.0 * (1.0 - used / exact)
    return [Finding(
        "ENE001", "info", "energy",
        f"estimated multiply energy {used:.2f} uJ vs all-exact "
        f"{exact:.2f} uJ ({saved:+.1f}% saved) over {graph.total_macs():,d} "
        f"MACs / {len(graph.sites)} sites")]


def _sev(advisory: bool) -> str:
    return "warning" if advisory else "error"


def check_serving(graph: SiteGraph, engine_cfg=None, *,
                  advisory: bool = False) -> List[Finding]:
    """Serving-config lints against the traced model (paged engine)."""
    from repro.serve.engine import EngineConfig

    if graph.cfg.family not in ("dense", "moe"):
        return [Finding(
            "SRV006", "info", "serving",
            f"family '{graph.cfg.family}' has no paged decode path; "
            "serving checks skipped")]
    findings = []
    if engine_cfg is None:
        engine_cfg = EngineConfig()
    if graph.cfg.window:
        findings.append(Finding(
            "SRV001", _sev(advisory), "serving",
            f"ArchConfig.window={graph.cfg.window} is incompatible with "
            "the paged KV cache (ring buffers roll in place, pages are "
            "freed whole); serve with window=0 or the slot engine"))

    capacity = engine_cfg.blocks * engine_cfg.block_size
    if capacity < engine_cfg.max_seq:
        findings.append(Finding(
            "SRV002", _sev(advisory), "serving",
            f"KV pool holds {capacity} tokens ({engine_cfg.blocks} pages x "
            f"{engine_cfg.block_size}) < max_seq={engine_cfg.max_seq}: a "
            "max-length request can never be admitted; add pages or lower "
            "max_seq"))
    groups = max(1, len(engine_cfg.tiers))
    demand = engine_cfg.num_slots * groups * engine_cfg.max_seq
    if capacity < demand and capacity >= engine_cfg.max_seq:
        findings.append(Finding(
            "SRV003", "warning", "serving",
            f"KV pool ({capacity} tokens) covers only "
            f"{capacity / demand:.0%} of peak demand (num_slots="
            f"{engine_cfg.num_slots} x {groups} policy group(s) x max_seq="
            f"{engine_cfg.max_seq} = {demand}): full-width decode at max "
            "length will stall on page allocation"))

    site_keys = [(s.path, s.kind) for s in graph.sites]
    tier_groups = {}
    for name, spec in engine_cfg.tiers:
        try:
            pol = parse_policy(spec, name=name)
        except ValueError as e:
            findings.append(Finding(
                "SRV005", _sev(advisory), "serving",
                f"tier '{name}' policy spec rejected: {e}", site=name))
            continue
        key = dataclasses.replace(pol, name="")
        tier_groups.setdefault(key, []).append(name)
        for i, rule in enumerate(pol.rules):
            if not any(rule.matches(p, k) for p, k in site_keys):
                findings.append(Finding(
                    "SRV005", "warning", "serving",
                    f"tier '{name}' rule {i} ({rule.pattern}) matches no "
                    f"op-site of {graph.cfg.name}; the tier silently "
                    "degrades to its remaining rules", site=name))
        for where, dcfg in [(f"tier '{name}' rule {i} ({r.pattern})", r.config)
                            for i, r in enumerate(pol.rules)] + [
                                (f"tier '{name}' default", pol.default)]:
            try:
                validate_for_dtype(dcfg, graph.cfg.compute_dtype, site=where)
            except ValueError as e:
                findings.append(Finding("SRV005", _sev(advisory), "serving",
                                        str(e), site=name))
    for names in tier_groups.values():
        if len(names) > 1:
            findings.append(Finding(
                "SRV004", "warning", "serving",
                f"tiers {names} resolve to the same policy group — they "
                "share one jit'd step and one decode batch; merge them or "
                "differentiate the specs", site=names[0]))
    if engine_cfg.shards > 1 and (engine_cfg.blocks % engine_cfg.shards
                                  or engine_cfg.num_slots % engine_cfg.shards):
        findings.append(Finding(
            "SRV007", _sev(advisory), "serving",
            f"blocks={engine_cfg.blocks} / num_slots={engine_cfg.num_slots} "
            f"not divisible by the mesh serving-axis size "
            f"({engine_cfg.shards} shards): the Sharder's divisibility "
            "fallback silently replicates the KV pool and decode batch "
            "instead of sharding them — size both as multiples of shards"))
    if (engine_cfg.preempt and engine_cfg.swap_blocks
            and engine_cfg.swap_blocks < engine_cfg.max_blocks_per_seq):
        findings.append(Finding(
            "SRV008", "warning", "serving",
            f"preemption enabled with swap_blocks={engine_cfg.swap_blocks} "
            f"< one max-length request ({engine_cfg.max_blocks_per_seq} "
            "pages): a long-running victim cannot be swapped out, so "
            "exhaustion degrades to stalls; raise swap_blocks or leave it "
            "0 (auto: one full request)"))
    if getattr(engine_cfg, "spec_k", 0):
        findings += _check_spec_draft(graph, engine_cfg, advisory=advisory)
    return findings


def _check_spec_draft(graph: SiteGraph, engine_cfg, *,
                      advisory: bool = False) -> List[Finding]:
    """SRV009: the self-speculative draft policy must be compatible with
    the verify target. Three ways it can fail:

    * a windowed model — draft steps write K/V ``spec_k`` positions ahead
      of the committed length, and a rolling ring buffer can wrap those
      writes onto live history before verify overwrites them;
    * the draft tier is illegal for the model's compute dtype (LUT backend
      or flash-attention DAISM variants off bf16) — the draft jit would
      raise at the first speculative step, long after launch;
    * the draft policy is not actually cheaper than the target under the
      analyzer's energy model — speculation then burns more multiply
      energy per accepted token than plain decode, silently.
    """
    from repro.policy import effective_attn_config, energy_per_mult_pj

    findings = []
    spec = dict(engine_cfg.tiers).get(engine_cfg.spec_draft,
                                      engine_cfg.spec_draft)
    try:
        draft = parse_policy(spec, name="spec-draft")
    except ValueError as e:
        return [Finding(
            "SRV009", _sev(advisory), "serving",
            f"speculative draft spec '{engine_cfg.spec_draft}' rejected: "
            f"{e}", site="spec_draft")]
    if graph.cfg.window:
        findings.append(Finding(
            "SRV009", _sev(advisory), "serving",
            f"speculative decoding (spec_k={engine_cfg.spec_k}) on a "
            f"windowed model (window={graph.cfg.window}): draft steps "
            "write K/V ahead of the committed length and a rolling window "
            "can wrap those writes onto live history; serve with window=0",
            site="spec_draft"))
    for where, dcfg in [(f"draft rule {i} ({r.pattern})", r.config)
                        for i, r in enumerate(draft.rules)] + [
                            ("draft default", draft.default)]:
        try:
            validate_for_dtype(dcfg, graph.cfg.compute_dtype, site=where)
        except ValueError as e:
            findings.append(Finding(
                "SRV009", _sev(advisory), "serving",
                f"speculative {e}", site="spec_draft"))
    def _policy_uj(pol) -> float:
        total = 0.0
        for s in graph.sites:
            resolved = pol.resolve(s.path, s.kind)
            if s.kind is OpKind.ATTN_QK:
                resolved = effective_attn_config(resolved)
            total += s.macs * energy_per_mult_pj(resolved, s.dtype)
        return total * 1e-6

    draft_uj = _policy_uj(draft)
    draft_key = dataclasses.replace(draft, name="")
    target_uj, _ = graph.energy_uj()
    # sums accumulate in different orders; 1e-9 relative slack keeps
    # "equal energy" (draft == target policy) on the error side
    if target_uj > 0 and draft_uj >= target_uj * (1 - 1e-9):
        findings.append(Finding(
            "SRV009", _sev(advisory), "serving",
            f"speculative draft policy is not cheaper than the target "
            f"({draft_uj:.2f} uJ vs {target_uj:.2f} uJ per forward under "
            "the energy model): every rejected draft token costs more "
            "than the exact decode it replaces; pick a cheaper draft "
            "tier or disable speculation", site="spec_draft"))
    for name, tier_spec in engine_cfg.tiers:
        try:
            pol = parse_policy(tier_spec, name=name)
        except ValueError:
            continue  # already reported as SRV005
        if dataclasses.replace(pol, name="") == draft_key:
            continue  # engine disables speculation for the draft's own group
        tier_uj = _policy_uj(pol)
        if tier_uj > 0 and draft_uj >= tier_uj * (1 - 1e-9):
            findings.append(Finding(
                "SRV009", "warning", "serving",
                f"speculative draft is not cheaper than tier '{name}' "
                f"({draft_uj:.2f} uJ vs {tier_uj:.2f} uJ): that group's "
                "draft steps cost at least as much as the decode steps "
                "they try to skip", site="spec_draft"))
    return findings


def run_checkers(graph: SiteGraph, engine_cfg=None, *,
                 serving: bool = True, advisory_serving: bool = False,
                 vmem_budget_mib: float = 16.0, max_segments: int = 4,
                 max_kernel_variants: int = 8
                 ) -> "tuple[List[Finding], tuple]":
    """Run every checker; returns (findings, categories_checked)."""
    findings = []
    findings += check_policy(graph)
    findings += check_backend(graph)
    findings += check_tiling(graph, vmem_budget_mib=vmem_budget_mib)
    findings += check_attention(graph)
    findings += check_recompile(graph, max_segments=max_segments,
                                max_kernel_variants=max_kernel_variants)
    findings += check_energy(graph)
    categories = ["policy", "backend", "tiling", "recompile", "energy"]
    if serving:
        findings += check_serving(graph, engine_cfg,
                                  advisory=advisory_serving)
        categories.append("serving")
    order = {s: i for i, s in enumerate(SEVERITIES)}
    findings.sort(key=lambda f: (order[f.severity], f.category, f.code))
    return findings, tuple(categories)


def engine_config_finding(err: Exception) -> Finding:
    """Wrap an EngineConfig construction error as a finding (SRV000)."""
    return Finding("SRV000", "error", "serving", str(err))
