"""daism-lint: static analysis of (model, policy, engine) triples.

The analyzer abstract-interprets a registered model config under an
``ApproxPolicy`` with ``jax.eval_shape`` — no weights allocated, no kernels
run — materializes the complete op-site graph, and runs pluggable checkers
over it (policy reachability, backend legality, Pallas tiling, recompile
hazards, serving config). See ``launch/lint.py`` for the CLI and
``analyze/checkers.py`` for the lint-code table.

Quick start::

    from repro.analyze import analyze, format_text

    report = analyze("tinyllama_1_1b", "*/attn/*=exact,*=pc3_tr")
    print(format_text(report))
    raise SystemExit(report.exit_code)
"""
from __future__ import annotations

from typing import Optional

from .checkers import (CATEGORIES, Finding, check_attention, check_backend,
                       check_energy, check_policy, check_recompile,
                       check_serving, check_tiling, engine_config_finding,
                       run_checkers)
from .report import AnalysisReport, format_json, format_text
from .sitegraph import SiteGraph, SiteRecord, trace_site_graph

__all__ = [
    "analyze", "preflight", "AnalysisReport", "Finding",
    "SiteGraph", "SiteRecord", "trace_site_graph", "run_checkers",
    "check_policy", "check_backend", "check_tiling", "check_attention",
    "check_recompile", "check_energy", "check_serving",
    "engine_config_finding",
    "format_text", "format_json", "CATEGORIES",
]


def analyze(cfg, policy=None, *, engine_cfg=None, serving: bool = True,
            advisory_serving: bool = False, batch: int = 1, seq: int = 8,
            vmem_budget_mib: float = 16.0, max_segments: int = 4,
            max_kernel_variants: int = 8) -> AnalysisReport:
    """Lint ``cfg`` (an ArchConfig or a registered arch name) under
    ``policy`` (None = the config's own, a spec string, or an ApproxPolicy).

    ``engine_cfg`` focuses the serving checks on a concrete deployment;
    without one they run against the default ``EngineConfig``.
    ``advisory_serving`` caps serving findings at warning severity (the
    CI sweep mode, where no deployment is actually being launched).
    """
    if isinstance(cfg, str):
        from repro.configs import get_config
        cfg = get_config(cfg)
    graph = trace_site_graph(cfg, policy, batch=batch, seq=seq)
    findings, categories = run_checkers(
        graph, engine_cfg, serving=serving,
        advisory_serving=advisory_serving, vmem_budget_mib=vmem_budget_mib,
        max_segments=max_segments, max_kernel_variants=max_kernel_variants)
    return AnalysisReport(graph=graph, findings=findings,
                          categories=categories)


def preflight(cfg, policy=None, *, engine_cfg=None, serving: bool = True,
              label: str = "preflight",
              strict: bool = True) -> Optional[AnalysisReport]:
    """Launcher hook: lint before committing to params/compilation.

    Prints findings (site table omitted), raises ``SystemExit`` on
    error-severity findings when ``strict``. Returns the report.
    """
    report = analyze(cfg, policy, engine_cfg=engine_cfg, serving=serving)
    visible = [f for f in report.findings if f.severity != "info"]
    if visible:
        print(f"-- {label}: daism-lint --")
        for f in visible:
            print(f"  {f}")
    if strict and report.errors:
        raise SystemExit(
            f"{label}: daism-lint found {len(report.errors)} error(s) — "
            "fix the policy/engine config or pass --no-preflight")
    return report
