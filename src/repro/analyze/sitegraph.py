"""Abstract op-site graph materialization (no weights, no kernels).

``trace_site_graph`` abstract-interprets a registered model config under an
:class:`~repro.policy.ApproxPolicy` with ``jax.eval_shape``: the model's
forward pass is traced with ``ShapeDtypeStruct`` stand-ins, every contraction
resolves through the policy dispatcher as usual, and a site observer
(:func:`repro.policy.observe_sites`) captures the full op-site graph — path,
:class:`OpKind`, GEMM dims, operand dtype, resolved :class:`DaismConfig`,
MAC count — without allocating a single weight or running a single kernel.

The candidate policy may be *invalid* (e.g. a bf16-only backend on an fp32
model): ``ArchConfig`` would reject it at construction, so the trace runs
under a segmentation-preserving rewrite (every distinct config mapped
injectively to a distinct always-legal exact config) and the real policy is
re-resolved per site afterwards. Checkers then report legality findings
instead of the construction crash.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import Backend, DaismConfig, Variant
from repro.models.common import ArchConfig
from repro.policy import (ApproxPolicy, OpKind, energy_per_mult_pj,
                          observe_sites, parse_policy)

PolicyLike = Union[None, str, ApproxPolicy]


@dataclasses.dataclass(frozen=True)
class SiteRecord:
    """One contraction site of the traced model under the analyzed policy."""

    path: str
    kind: OpKind
    config: DaismConfig        # resolved under the *candidate* policy
    dtype: str                 # operand dtype name at the site
    dims: Tuple[int, int, int]  # (m, k, n) of one kernel invocation
    macs: int                  # total multiplies (expert batching + repeat)
    repeat: int                # ambient scan repeat (segment length)

    @property
    def energy_pj(self) -> float:
        return self.macs * energy_per_mult_pj(self.config, self.dtype)

    @property
    def exact_energy_pj(self) -> float:
        exact = DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT)
        return self.macs * energy_per_mult_pj(exact, self.dtype)


@dataclasses.dataclass(frozen=True)
class SiteGraph:
    """The complete op-site graph of one (model config, policy) pair."""

    cfg: ArchConfig
    policy: ApproxPolicy
    sites: Tuple[SiteRecord, ...]
    # scanned-stack segmentation, e.g. {"segments": ((0, 22),)} — one entry
    # per stack attribute of the traced model (enc/dec stacks separately)
    segments: Dict[str, Tuple[Tuple[int, int], ...]]

    def energy_uj(self) -> Tuple[float, float]:
        """(policy_energy, all_exact_energy) in uJ over the whole graph."""
        total = sum(s.energy_pj for s in self.sites)
        base = sum(s.exact_energy_pj for s in self.sites)
        return total / 1e6, base / 1e6

    def total_macs(self) -> int:
        return sum(s.macs for s in self.sites)

    def paths(self) -> Tuple[str, ...]:
        return tuple(s.path for s in self.sites)


def _as_policy(cfg: ArchConfig, policy: PolicyLike) -> ApproxPolicy:
    if policy is None:
        return cfg.approx_policy
    if isinstance(policy, str):
        return parse_policy(policy)
    return policy


def _safe_rewrite(policy: ApproxPolicy) -> ApproxPolicy:
    """Segmentation-preserving legality rewrite.

    Maps every distinct config the policy can resolve to onto a distinct
    exact config (disambiguated through ``k_chunk``, which nothing
    validates against the compute dtype). The map is injective, so
    ``plan_segments`` partitions layers identically under the rewrite —
    the traced site paths (``layer_{lo}`` segment labels included) are
    exactly the ones the real policy would produce — while the trace can
    never trip ``validate_for_dtype`` on a deliberately broken candidate.

    ``attn_kernel`` is carried over: segmentation fingerprints collapse
    non-flash ATTN_QK resolutions to one effective EXACT config
    (``policy.layer_signature``), so dropping the flag here would merge
    layer runs the real policy keeps apart. Exact flash configs are legal
    on every dtype, so carrying the flag cannot re-introduce a validation
    crash.
    """
    mapping: Dict[DaismConfig, DaismConfig] = {}

    def safe(c: DaismConfig) -> DaismConfig:
        if c not in mapping:
            mapping[c] = DaismConfig(variant=Variant.EXACT,
                                     backend=Backend.EXACT,
                                     k_chunk=10_000 + len(mapping),
                                     attn_kernel=c.attn_kernel)
        return mapping[c]

    rules = tuple(dataclasses.replace(r, config=safe(r.config))
                  for r in policy.rules)
    return ApproxPolicy(rules=rules, default=safe(policy.default),
                        name=policy.name)


def _input_specs(cfg: ArchConfig, *, batch: int, seq: int):
    """Small ShapeDtypeStruct inputs covering every family's forward."""
    if cfg.family == "cnn":
        side, chan = ((28, 1) if "lenet" in cfg.name else (32, 3))
        return {"images": jax.ShapeDtypeStruct((batch, side, side, chan),
                                               jnp.float32)}
    i32 = jnp.int32
    dt = jnp.dtype(cfg.compute_dtype)
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), dt)
    return specs


def _collect_segments(model) -> Dict[str, Tuple[Tuple[int, int], ...]]:
    out = {}
    for attr in ("segments", "enc_segments", "dec_segments"):
        segs = getattr(model, attr, None)
        if segs:
            out[attr] = tuple(tuple(s) for s in segs)
    return out


def trace_site_graph(cfg: ArchConfig, policy: PolicyLike = None, *,
                     batch: int = 1, seq: int = 8) -> SiteGraph:
    """Materialize the op-site graph of ``cfg`` under ``policy``.

    Pure shape-level work: ``model.init(abstract=True)`` +
    ``jax.eval_shape`` over the forward pass. ``policy`` may be ``None``
    (the config's own effective policy), a spec string, or an
    ``ApproxPolicy`` — including ones ``ArchConfig`` itself would reject.
    """
    from repro.models.registry import build_model

    candidate = _as_policy(cfg, policy)
    trace_cfg = dataclasses.replace(
        cfg, policy=_safe_rewrite(candidate),
        daism=DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT))
    model = build_model(trace_cfg)
    params, _ = model.init(jax.random.PRNGKey(0), abstract=True)

    events = []
    with observe_sites(events.append):
        jax.eval_shape(model.forward, params,
                       _input_specs(cfg, batch=batch, seq=seq))

    from repro.policy import effective_attn_config

    seen = {}
    for ev in events:
        # candidate and rewritten policy share rule patterns/order, so
        # re-resolving the candidate picks the same winning rule per site
        resolved = candidate.resolve(ev.path, ev.kind)
        if ev.kind is OpKind.ATTN_QK:
            # the graph records what the site *runs*: attention numerics
            # apply only under ':flash' dispatch, else effectively EXACT
            resolved = effective_attn_config(resolved)
        seen[(ev.path, ev.kind)] = SiteRecord(
            path=ev.path, kind=ev.kind, config=resolved,
            dtype=ev.dtype, dims=ev.dims, macs=ev.macs, repeat=ev.repeat)
    sites = tuple(seen[k] for k in sorted(seen, key=lambda k: k[0]))
    return SiteGraph(cfg=cfg, policy=candidate, sites=sites,
                     segments=_collect_segments(model))
