"""Analysis report container + text/json rendering for daism-lint."""
from __future__ import annotations

import dataclasses
import json
from typing import List, Tuple

from repro.policy import describe_config

from .checkers import Finding
from .sitegraph import SiteGraph

_ICON = {"error": "E", "warning": "W", "info": "I"}


@dataclasses.dataclass
class AnalysisReport:
    """Everything one lint run produced: the graph and the findings."""

    graph: SiteGraph
    findings: List[Finding]
    categories: Tuple[str, ...]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def exit_code(self) -> int:
        return 1 if self.errors else 0

    def counts(self) -> dict:
        by_cat = {c: 0 for c in self.categories}
        for f in self.findings:
            if f.severity != "info":
                by_cat[f.category] = by_cat.get(f.category, 0) + 1
        return by_cat


def _site_table(graph: SiteGraph) -> List[str]:
    if not graph.sites:
        return ["  (no contraction sites traced)"]
    width = max(len(s.path) for s in graph.sites)
    lines = []
    for s in graph.sites:
        m, k, n = s.dims
        rep = f" x{s.repeat}" if s.repeat > 1 else ""
        lines.append(
            f"  {s.path:<{width}}  {s.kind.value:<10s} "
            f"{describe_config(s.config):<18s} {s.dtype:<9s} "
            f"({m}x{k}x{n}){rep:<5s} {s.macs:>14,d} MACs "
            f"{s.energy_pj / 1e6:>9.3f} uJ")
    return lines


def format_text(report: AnalysisReport, *, sites: bool = True) -> str:
    graph = report.graph
    used, exact = graph.energy_uj()
    head = (f"== daism-lint: {graph.cfg.name} under policy "
            f"{graph.policy.name or '<anonymous>'} ==")
    lines = [head]
    if sites:
        lines += _site_table(graph)
    for stack, segs in graph.segments.items():
        lines.append(f"  {stack}: {len(segs)} scan segment(s) "
                     + " ".join(f"[{lo},{hi})" for lo, hi in segs))
    lines.append("")
    for f in report.findings:
        where = f"  [{f.site}]" if f.site else ""
        lines.append(f"{_ICON[f.severity]} {f.code} ({f.category}) "
                     f"{f.message}{where}")
    n_err, n_warn = len(report.errors), len(report.warnings)
    checked = ", ".join(
        f"{c}:{'FAIL' if any(x.category == c and x.severity == 'error' for x in report.findings) else 'ok'}"
        for c in report.categories)
    lines.append(f"{len(report.categories)} checkers [{checked}] — "
                 f"{n_err} error(s), {n_warn} warning(s); estimated energy "
                 f"{used:.2f}/{exact:.2f} uJ (policy/exact)")
    return "\n".join(lines)


def format_json(report: AnalysisReport) -> str:
    graph = report.graph
    used, exact = graph.energy_uj()
    payload = {
        "model": graph.cfg.name,
        "policy": graph.policy.name or "<anonymous>",
        "categories": list(report.categories),
        "exit_code": report.exit_code,
        "energy_uj": {"policy": used, "exact": exact},
        "segments": {k: [list(s) for s in v]
                     for k, v in graph.segments.items()},
        "sites": [
            {"path": s.path, "kind": s.kind.value,
             "config": describe_config(s.config), "dtype": s.dtype,
             "dims": list(s.dims), "macs": s.macs, "repeat": s.repeat,
             "energy_uj": s.energy_pj / 1e6}
            for s in graph.sites],
        "findings": [
            {"code": f.code, "severity": f.severity, "category": f.category,
             "message": f.message, "site": f.site}
            for f in report.findings],
    }
    return json.dumps(payload, indent=2)
