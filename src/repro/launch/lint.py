"""daism-lint CLI: static preflight for (model, policy, engine) triples.

    PYTHONPATH=src python -m repro.launch.lint \
        --model tinyllama_1_1b --policy "*/attn/*=exact,*=pc3_tr"

Abstract-interprets the model under the policy with ``jax.eval_shape`` (no
weights allocated, no kernels run), prints the op-site table, and runs the
full checker suite — policy reachability, backend legality, Pallas tiling,
recompile hazards, energy summary, serving config. Exits 1 on any
error-severity finding, so it gates CI and the train/serve launchers.

``--all`` lints every registered config (the CI ``lint-policies`` job);
serving findings are advisory there since no deployment is being launched.
"""
import argparse
import sys


def _engine_cfg(args):
    """Build the EngineConfig under lint (None = construction error
    already reported by the caller)."""
    from repro.serve.engine import EngineConfig, parse_tiers

    tiers = parse_tiers(args.tiers) if args.tiers else ()
    return EngineConfig(num_slots=args.slots, max_seq=args.max_seq,
                        block_size=args.block_size, num_blocks=args.blocks,
                        prefill_chunk=args.prefill_chunk, tiers=tiers,
                        shards=args.shards, preempt=args.preempt,
                        swap_blocks=args.swap_blocks,
                        spec_draft=args.spec_draft, spec_k=args.spec_k)


def _lint_one(name, args, *, advisory):
    from repro.analyze import (AnalysisReport, analyze, engine_config_finding,
                               run_checkers, trace_site_graph)
    from repro.configs import get_config

    cfg = get_config(name)
    try:
        engine_cfg = _engine_cfg(args)
    except ValueError as e:
        # the engine config itself is broken: still trace + run the other
        # checkers, with the construction error as an SRV000 finding
        graph = trace_site_graph(cfg, args.policy or None, seq=args.seq)
        findings, categories = run_checkers(graph, None, serving=False)
        findings.insert(0, engine_config_finding(e))
        return AnalysisReport(graph=graph, findings=findings,
                              categories=(*categories, "serving"))
    return analyze(cfg, args.policy or None, engine_cfg=engine_cfg,
                   advisory_serving=advisory, seq=args.seq)


def main(argv=None):
    p = argparse.ArgumentParser(prog="daism-lint", description=__doc__)
    p.add_argument("--model", "--arch", dest="model", default="",
                   help="registered config name (see repro.configs)")
    p.add_argument("--all", action="store_true",
                   help="lint every registered config (serving advisory)")
    p.add_argument("--policy", default="",
                   help="candidate policy spec, e.g. '*/attn/*=exact,"
                        "*=pc3_tr' (default: the config's own policy)")
    p.add_argument("--tiers", default="",
                   help="serving tier specs 'name=spec;...' to lint against "
                        "the model (repro.serve.parse_tiers form)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--no-sites", action="store_true",
                   help="omit the per-site table from text output")
    p.add_argument("--seq", type=int, default=8,
                   help="abstract trace sequence length")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--blocks", type=int, default=0)
    p.add_argument("--prefill-chunk", type=int, default=16)
    p.add_argument("--shards", type=int, default=1,
                   help="mesh serving-axis size the engine is laid out for")
    p.add_argument("--preempt", action="store_true",
                   help="lint with preemption/swap admission enabled")
    p.add_argument("--swap-blocks", type=int, default=0,
                   help="host swap buffer pages (0 = one full request)")
    p.add_argument("--spec-draft", default="",
                   help="speculative draft policy: a --tiers name or a raw "
                        "spec (lints compatibility with the model, SRV009)")
    p.add_argument("--spec-k", type=int, default=0,
                   help="draft tokens per speculative verify step")
    args = p.parse_args(argv)
    if bool(args.model) == args.all:
        p.error("exactly one of --model or --all is required")

    from repro.analyze import format_json, format_text
    from repro.configs import ARCH_IDS, PAPER_IDS

    names = (ARCH_IDS + PAPER_IDS) if args.all else (args.model,)
    worst = 0
    for name in names:
        report = _lint_one(name, args, advisory=args.all)
        if args.format == "json":
            print(format_json(report))
        else:
            print(format_text(report, sites=not (args.no_sites or args.all)))
        worst = max(worst, report.exit_code)
    if args.all:
        print(f"daism-lint: {len(names)} configs linted, "
              f"{'FAIL' if worst else 'ok'}")
    return sys.exit(worst) if worst else 0


if __name__ == "__main__":
    main()
