"""Builders for the distributed train / prefill / decode steps.

``build_artifacts`` assembles, for one (arch x mesh) pair: the model, the
sharder (logical-axis rules), abstract param/optimizer/cache trees, their
NamedShardings, and jit-compiled step functions with explicit in/out
shardings and donated buffers. The dry-run lowers these exact functions; the
trainer executes them — one code path for both (no fake dry-run graph).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig
from repro.models.module import axes_tree
from repro.models.registry import build_model, lm_loss
from repro.optim import (AdamWConfig, AdamWState, abstract_state,
                         apply_updates, cosine_with_warmup, init_state,
                         state_axes)
from repro.parallel.sharding import (Sharder, base_rules, tree_shardings,
                                     use_sharder)


# ---------------------------------------------------------------------------
# Cache logical axes (keyed by leaf name + rank — uniform across model zoo)
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    ("k", 5): ("layers", "cache_batch", "cache_seq", "act_kv_heads", None),
    ("v", 5): ("layers", "cache_batch", "cache_seq", "act_kv_heads", None),
    ("k", 4): ("cache_batch", "cache_seq", "act_kv_heads", None),
    ("v", 4): ("cache_batch", "cache_seq", "act_kv_heads", None),
    ("abs_pos", 2): ("layers", "cache_seq"),
    ("abs_pos", 1): ("cache_seq",),
    ("conv", 4): ("layers", "cache_batch", None, "act_mlp"),
    ("ssm", 5): ("layers", "cache_batch", "act_heads", None, None),
    ("enc", 3): ("cache_batch", "frames", "act_embed"),
    ("pos", 0): (),
}


def cache_axes(cache) -> Any:
    def walk(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        rank = len(leaf.shape)
        if (name, rank) in _CACHE_AXES:
            return _CACHE_AXES[(name, rank)]
        # xlstm state tuples & misc: batch dim after the stacked layer dim
        if rank == 0:
            return ()
        if rank >= 2:
            return ("layers", "cache_batch") + (None,) * (rank - 2)
        return (None,) * rank

    return jax.tree_util.tree_map_with_path(walk, cache)


# ---------------------------------------------------------------------------
# Artifacts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Artifacts:
    cfg: ArchConfig
    mesh: Mesh
    sharder: Sharder
    model: Any
    param_shapes: Any
    param_shardings: Any
    param_axes: Any
    opt_shapes: Any
    opt_shardings: Any
    train_step: Any          # jitted (params, opt, batch) -> (params, opt, metrics)
    prefill_step: Any        # jitted (params, batch) -> logits
    decode_step: Any         # jitted (params, tokens, cache) -> (logits, cache)
    make_cache_shapes: Callable[[int, int], Any]
    cache_shardings_for: Callable[[Any], Any]
    batch_sharding: Callable[[Any], Any]
    init_params: Callable[[jax.Array], Any]
    init_opt: Callable[[Any], Any]


def build_artifacts(cfg: ArchConfig, mesh: Mesh, *,
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    rules: Optional[dict] = None,
                    total_steps: int = 100_000,
                    warmup: int = 1000,
                    donate: bool = True) -> Artifacts:
    multi_pod = "pod" in mesh.axis_names
    rules = rules or base_rules(multi_pod)
    sharder = Sharder(mesh, rules)
    model = build_model(cfg)

    with use_sharder(sharder):
        param_shapes, axes = model.init(jax.random.PRNGKey(0), abstract=True)
    param_axes = axes_tree(param_shapes, axes)
    param_shardings = tree_shardings(sharder, param_shapes, param_axes)
    opt_shapes = abstract_state(param_shapes)
    opt_axes = state_axes(param_axes)
    opt_shardings = tree_shardings(sharder, opt_shapes, opt_axes)

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def batch_sharding(batch_tree):
        def leaf(x):
            # leading dim is the global batch; divisibility-aware (long_500k
            # decodes batch=1: replicate instead of crashing)
            axes = ("act_batch",) + (None,) * (len(x.shape) - 1)
            return sharder.sharding(axes, x.shape)
        return jax.tree.map(leaf, batch_tree)

    def cache_shardings_for(cache_tree):
        caxes = cache_axes(cache_tree)
        return tree_shardings(sharder, cache_tree, caxes)

    # -- step functions (traced under the sharder so constraints + MoE
    #    shard_map see the mesh) ------------------------------------------
    def train_step(params, opt_state, batch):
        with use_sharder(sharder):
            def loss_fn(p):
                logits, aux = model.forward(p, batch)
                return lm_loss(logits, batch["labels"], aux)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            lr_scale = cosine_with_warmup(opt_state.step, warmup=warmup,
                                          total=total_steps)
            new_params, new_opt, metrics = apply_updates(
                params, grads, opt_state, opt_cfg, lr_scale)
            metrics["loss"] = loss
            return new_params, new_opt, metrics

    def prefill_step(params, batch):
        with use_sharder(sharder):
            logits, _ = model.forward(params, batch)
            return logits

    def decode_step(params, tokens, cache, extra=None):
        with use_sharder(sharder):
            if cfg.family == "vlm":
                return model.decode_step(params, tokens, cache,
                                         image_embeds=extra)
            return model.decode_step(params, tokens, cache)

    scalar_sh = NamedSharding(mesh, P())
    train_jit = jax.jit(
        train_step,
        donate_argnums=(0, 1) if donate else (),
        out_shardings=(param_shardings, _opt_sh(opt_shardings, scalar_sh),
                       None),
    )
    prefill_jit = jax.jit(prefill_step)
    decode_jit = jax.jit(decode_step, donate_argnums=(2,) if donate else ())

    def make_cache_shapes(batch_size: int, max_seq: int):
        return model.init_cache(batch_size, max_seq, abstract=True)

    def init_params(rng):
        with use_sharder(sharder):
            init = jax.jit(lambda r: model.init(r)[0],
                           out_shardings=param_shardings)
            return init(rng)

    def init_opt(params):
        return jax.jit(init_state,
                       out_shardings=_opt_sh(opt_shardings, scalar_sh)
                       )(params)

    return Artifacts(
        cfg=cfg, mesh=mesh, sharder=sharder, model=model,
        param_shapes=param_shapes, param_shardings=param_shardings,
        param_axes=param_axes,
        opt_shapes=opt_shapes, opt_shardings=opt_shardings,
        train_step=train_jit, prefill_step=prefill_jit,
        decode_step=decode_jit,
        make_cache_shapes=make_cache_shapes,
        cache_shardings_for=cache_shardings_for,
        batch_sharding=batch_sharding,
        init_params=init_params, init_opt=init_opt,
    )


def _opt_sh(opt_shardings: AdamWState, scalar_sh) -> AdamWState:
    return AdamWState(scalar_sh, opt_shardings.master, opt_shardings.m,
                      opt_shardings.v)
