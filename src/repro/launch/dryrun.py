import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 placeholder host devices back the production
meshes: 16x16 single-pod and 2x16x16 multi-pod.

For every live cell (DESIGN.md §4 skip table):
  * build the real train/prefill/decode step via launch.steps (the same
    functions the trainer executes — no separate dry-run graph),
  * ``.lower(**ShapeDtypeStructs).compile()``,
  * record ``memory_analysis()`` / ``cost_analysis()`` / the HLO collective
    schedule -> roofline terms (roofline/analysis.py),
  * append to ``results/dryrun.json`` (resumable: done cells are skipped).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  ... --arch tinyllama_1_1b --shape train_4k --mesh multi      # one cell
  ... --rules seqcache                                         # perf variant
"""

import argparse
import contextlib
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp


RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results")


def _mesh(kind: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


def _specs_with_shardings(tree, shardings):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        tree, shardings)


def loop_accounting(cfg, kind: str, seq: int):
    """Scan-undercount correction plan (EXPERIMENTS.md §Roofline methodology).

    Returns [{cat, k, mult}]: compile a probe with scan category ``cat``
    unrolled by ``k``; true_cost = base + (probe - base) * mult / (k - 1).
    ``mult`` encodes trip counts and loop nesting per DESIGN.md §4 model
    structure; derivation in the module docstring of parallel/unroll.py.
    """
    import math
    chunk = cfg.attn_chunk
    cache_len = min(cfg.window, seq) if cfg.window else seq
    kv_len = cache_len if kind == "decode" else seq
    nc = max(1, math.ceil(kv_len / chunk))
    s_time = 1 if kind == "decode" else seq
    probes = []

    def add(cat, k, mult):
        if mult > 0 and k > 1:
            probes.append({"cat": cat, "k": k, "mult": float(mult)})

    fam = cfg.family
    if fam in ("dense", "moe"):
        t = cfg.n_layers
        add("layers", 2, t - 1)
        add("attn", 2, (nc - 1) * t)
    elif fam == "vlm":
        t = cfg.cross_every                      # 8 loops x trip 5
        add("layers", t, t - 1)                  # k=5 (divides trip exactly)
        add("attn", 2, (nc - 1) * t)
    elif fam == "audio":
        t = cfg.n_layers                         # enc + dec loops, trip 32
        add("layers", 2, t - 1)
        add("attn", 2, (nc - 1) * t)             # decoder self-attn
        if kind != "decode":                     # encoder runs in fwd only
            nc_enc = max(1, math.ceil(cfg.enc_frames / chunk))
            add("attn_enc", 2, (nc_enc - 1) * t)
    elif fam == "ssm":                           # xlstm
        group = (cfg.slstm_every or cfg.n_layers + 1) - 1
        add("layers", group, group - 1)          # k = trip (7: prime)
        # mlstm time scans sit inside layer loops (enclosing trip = group);
        # slstm time scans are top-level (python-applied blocks) -> exact
        add("time", 2, (s_time - 1) * group)
        add("time_s", 2, (s_time - 1))
    elif fam == "hybrid":                        # zamba
        every = cfg.shared_attn_every
        n_sites = cfg.n_layers // every if every else 0
        if n_sites:
            tail = cfg.n_layers - n_sites * every
            n_loops = n_sites + (1 if tail else 0)
            # homogeneous mamba bodies: sum(T_l - 1) spread over n_loops
            add("layers", 2, cfg.n_layers - n_loops)
            # shared-attn blocks are top-level -> exact (n-1) factor
            add("attn", 2, nc - 1)
            # time scans inside layer loops: scale by mean enclosing trip
            add("time", 2, (s_time - 1) * (cfg.n_layers / n_loops))
        else:
            add("layers", 2, cfg.n_layers - 1)
            add("time", 2, (s_time - 1))
    return probes


def lower_cell(cfg, mesh, rules, shape_name: str, *, probe_cat=None,
               probe_k=1):
    """Build fresh artifacts (fresh jit objects: trace caches must not leak
    across unroll probes) and lower the cell's step. Returns (lowered,
    flops_thunk) where flops_thunk() walks the jaxpr for exact FLOPs."""
    from repro.configs import SHAPES, input_specs
    from repro.launch.steps import build_artifacts
    from repro.parallel.unroll import use_unroll
    from repro.roofline.flops import count_flops

    seq, batch, kind = SHAPES[shape_name]
    art = build_artifacts(cfg, mesh, rules=rules)
    specs, kind = input_specs(cfg, shape_name)
    batch_specs = _specs_with_shardings(specs, art.batch_sharding(specs))
    params = _specs_with_shardings(art.param_shapes, art.param_shardings)

    ctx = (use_unroll(**{probe_cat: probe_k}) if probe_cat
           else contextlib.nullcontext())
    with ctx:
        if kind == "train":
            opt = _specs_with_shardings(art.opt_shapes, art.opt_shardings)
            lowered = art.train_step.lower(params, opt, batch_specs)
            flops_thunk = lambda: count_flops(
                art.train_step, params, opt, batch_specs)
        elif kind == "prefill":
            lowered = art.prefill_step.lower(params, batch_specs)
            flops_thunk = lambda: count_flops(
                art.prefill_step, params, batch_specs)
        else:  # decode
            cache_shapes = art.make_cache_shapes(batch, seq)
            cache = _specs_with_shardings(
                cache_shapes, art.cache_shardings_for(cache_shapes))
            toks = dict(batch_specs).pop("tokens")
            extra = batch_specs.get("image_embeds")
            lowered = art.decode_step.lower(params, toks, cache, extra)
            flops_thunk = lambda: count_flops(
                art.decode_step, params, toks, cache, extra)
    return lowered, flops_thunk, kind



def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             rules_name: str = "base", remat: str = "auto",
             tweaks: str = "", probes: bool = True,
             verbose: bool = True) -> Dict:
    from repro.configs import SHAPES, cell_enabled, get_config
    from repro.parallel.sharding import base_rules
    from repro.roofline import analysis as ra

    cfg = get_config(arch)
    if not cell_enabled(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skip", "reason": "mandated skip (DESIGN.md §4)"}
    seq, batch, kind0 = SHAPES[shape_name]
    if remat == "auto":
        remat = "full" if kind0 == "train" else "none"
    cfg = dataclasses.replace(cfg, remat=remat)
    if tweaks:  # e.g. "attn_score_dtype=bfloat16,rnn_state_dtype=bfloat16"
        kv = dict(t.split("=") for t in tweaks.split(","))
        cfg = dataclasses.replace(cfg, **kv)

    mesh = _mesh(mesh_kind)
    n_chips = mesh.devices.size
    rules = base_rules(mesh_kind == "multi",
                       seq_sharded_cache=(rules_name in ("seqcache",
                                                         "serve")),
                       sp_activations=(rules_name == "sp"),
                       serve=(rules_name == "serve"))

    t0 = time.monotonic()
    lowered, flops_thunk, kind = lower_cell(cfg, mesh, rules, shape_name)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0
    mem = compiled.memory_analysis()

    def costs_of(compiled_):
        cost = ra.xla_cost(compiled_)
        stats = ra.collective_bytes_from_hlo(compiled_.as_text(), n_chips)
        return (float(cost.get("bytes accessed", 0.0)), stats.wire_bytes,
                dict(stats.by_op))

    bytes0, wire0, by_op = costs_of(compiled)
    bytes_c, wire_c = bytes0, wire0
    probe_log = []
    if probes:  # scan-undercount correction (see loop_accounting)
        for probe in loop_accounting(cfg, kind, seq):
            plow, _, _ = lower_cell(cfg, mesh, rules, shape_name,
                                    probe_cat=probe["cat"],
                                    probe_k=probe["k"])
            pb, pw, pby = costs_of(plow.compile())
            scale = probe["mult"] / (probe["k"] - 1)
            bytes_c += max(pb - bytes0, 0.0) * scale
            wire_c += max(pw - wire0, 0.0) * scale
            for op, v in pby.items():
                extra = max(v - by_op.get(op, 0.0), 0.0) * scale
                by_op[op] = by_op.get(op, 0.0) + extra
            probe_log.append({**probe, "d_bytes": pb - bytes0,
                              "d_wire": pw - wire0})

    flops_global = flops_thunk()
    flops_dev = flops_global / n_chips
    model_flops = ra.model_flops_estimate(cfg, kind, seq, batch)
    compute_s = flops_dev / ra.PEAK_FLOPS
    memory_s = bytes_c / ra.HBM_BW
    coll_s = wire_c / ra.LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "rules": rules_name, "remat": remat, "tweaks": tweaks,
        "status": "ok",
        "kind": kind, "chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "out_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "mem_per_device_gb": round(
            (mem.argument_size_in_bytes + mem.output_size_in_bytes
             + mem.temp_size_in_bytes) / 2**30, 3),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_c,
        "wire_bytes_per_device": wire_c,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s,
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "useful_ratio": round(model_flops / max(flops_global, 1.0), 4),
        "collective_by_op": {k: round(v) for k, v in by_op.items()},
        "probes": probe_log,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind} ({rules_name})] "
              f"compile {t_compile:.0f}s mem/dev {rec['mem_per_device_gb']}GB "
              f"terms c={compute_s:.4f}s m={memory_s:.4f}s "
              f"coll={coll_s:.4f}s -> {bottleneck} "
              f"useful={rec['useful_ratio']:.2f}", flush=True)
    return rec


def _result_path(tag: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, f"dryrun_{tag}.json")


def load_results(tag: str = "main") -> Dict[str, Dict]:
    path = _result_path(tag)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def main():
    from repro.configs import ARCH_IDS, SHAPES

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    p.add_argument("--rules", default="base")
    p.add_argument("--remat", default="auto")
    p.add_argument("--tweaks", default="")
    p.add_argument("--tag", default="main")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    results = load_results(args.tag)
    path = _result_path(args.tag)
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                key = (f"{arch}|{shape}|{mesh_kind}|{args.rules}|"
                       f"{args.remat}|{args.tweaks}")
                if key in results and not args.force \
                        and results[key].get("status") in ("ok", "skip"):
                    continue
                try:
                    # roofline probes only on the single-pod mesh (the
                    # roofline table is single-pod; multi-pod proves the
                    # pod axis shards + records memory)
                    rec = run_cell(arch, shape, mesh_kind,
                                   rules_name=args.rules, remat=args.remat,
                                   tweaks=args.tweaks,
                                   probes=(mesh_kind == "single"))
                except Exception as e:  # record failures: they are bugs
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "rules": args.rules, "tweaks": args.tweaks,
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                results[key] = rec
                with open(path, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skip")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"dry-run: {n_ok} ok, {n_skip} mandated skips, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
