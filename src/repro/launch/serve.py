"""Serving launcher: batched prefill + greedy decode with KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --smoke --batch 4 --prompt-len 16 --gen 16

Demonstrates the production serve path: one prefill forward per request
batch, then serve_step (decode_step) per generated token against the cache.
"""
import argparse
import os


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--devices", type=int, default=0)
    args = p.parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count={args.devices}")
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.registry import build_model

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)

    max_seq = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_seq)
    decode = jax.jit(model.decode_step)

    # prefill by stepping the prompt through the cache (uniform code path;
    # a chunked prefill kernel is the production optimization, see §Perf)
    t0 = time.perf_counter()
    tok = prompts[:, :1]
    for t in range(args.prompt_len):
        logits, cache = decode(params, prompts[:, t:t + 1], cache)
    prefill_s = time.perf_counter() - t0

    out_tokens = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(args.gen):
        out_tokens.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prompts {prompts.shape} -> generated {gen.shape}")
    print(f"prefill {prefill_s*1e3:.1f} ms, decode "
          f"{decode_s / args.gen * 1e3:.2f} ms/token "
          f"({args.batch * args.gen / decode_s:.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
