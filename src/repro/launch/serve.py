"""Serving launcher: thin CLI over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b --smoke

Builds the model, submits a synthetic mixed-length workload, and drives
repro.serve.ServeEngine: batched prefill into a slot KV pool, one jit'd
decode step across all slots per token, finished sequences retire and
waiting requests join the running batch mid-stream. Prints the per-request
timeline and the engine's latency/throughput report.

``--policy "*/attn/*=exact,*=pc3_tr"`` serves with per-site DAISM numerics
(repro.policy); the legacy ``--variant pc3_tr`` flag still works through the
uniform-policy deprecation shim. After the run the per-site resolution
report (variant + estimated multiply energy per site) is printed. See
benchmarks/serve_bench.py and benchmarks/policy_sweep.py for numbers.
"""
import argparse
import dataclasses
import os
import warnings


def build_daism(variant: str, backend: str):
    from repro.core import Backend, DaismConfig, Variant
    return DaismConfig(variant=Variant(variant), backend=Backend(backend))


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + small workload (CPU-friendly)")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=2,
                   help="decode batch width / KV pool rows")
    p.add_argument("--max-seq", type=int, default=64,
                   help="per-slot KV capacity")
    p.add_argument("--prompt-len", type=int, default=8,
                   help="base prompt length (workload staggers around it)")
    p.add_argument("--gen", type=int, default=8,
                   help="base generation length")
    p.add_argument("--arrival-every", type=int, default=0,
                   help="space arrivals N engine steps apart (0 = all at once)")
    p.add_argument("--policy", default="",
                   help="per-site approximation policy spec, e.g. "
                        "'*/attn/*=exact,*/layer_0/*=exact,*=pc3_tr' "
                        "(repro.policy mini-language)")
    p.add_argument("--variant", default="exact",
                   help="DEPRECATED (use --policy): uniform multiplier "
                        "variant (exact | fla | ... | pc3_tr)")
    p.add_argument("--backend", default="jnp",
                   help="daism backend for approximate variants")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--devices", type=int, default=0)
    args = p.parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax

    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.serve import EngineConfig, ServeEngine, synthetic_requests

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke(window=0)  # slot pools need non-ring caches
    if args.policy:
        cfg = cfg.with_policy(args.policy)
    elif args.variant != "exact":
        warnings.warn("--variant/--backend are deprecated; use --policy "
                      f"'*={args.variant}:{args.backend}'", DeprecationWarning,
                      stacklevel=1)
        cfg = dataclasses.replace(cfg,
                                  daism=build_daism(args.variant, args.backend))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    engine = ServeEngine(model, params, EngineConfig(
        num_slots=args.slots, max_seq=args.max_seq))
    requests = synthetic_requests(
        args.requests, cfg.vocab, base_prompt=args.prompt_len,
        base_gen=args.gen, seed=args.seed, arrival_every=args.arrival_every)
    report = engine.run(requests)

    numerics = f"policy {args.policy}" if args.policy else args.variant
    print(f"== {args.arch} ({numerics}) — {args.requests} requests over "
          f"{args.slots} slots ==")
    for ev in report.events:
        if ev["event"] == "admit":
            joined = " (joined running batch)" if ev["joined_running"] else ""
            print(f"step {ev['step']:4d}  admit  req {ev['request_id']} "
                  f"-> slot {ev['slot']}{joined}")
        else:
            print(f"step {ev['step']:4d}  retire req {ev['request_id']} "
                  f"(slot {ev['slot']} freed, {ev['reason']})")
    print(report.summary())
    if args.policy or args.variant != "exact":
        print(engine.resolution_report())
    if report.completed:
        sample = report.completed[0]
        print(f"sample (req {sample.request_id}): {sample.output}")
    default_workload = all(
        getattr(args, k) == p.get_default(k)
        for k in ("requests", "slots", "gen", "prompt_len", "arrival_every"))
    if args.smoke and default_workload:
        # the gate is calibrated to the default smoke workload (staggered
        # lengths oversubscribing 2 slots); custom shapes — one slot, spaced
        # arrivals, equal-length retire waves — may legitimately never join
        if report.joined_mid_stream < 2:  # explicit: survives python -O
            raise SystemExit(
                "smoke workload must exercise continuous batching "
                f"(got {report.joined_mid_stream} mid-stream joins)")
        print("SMOKE-OK: continuous batching exercised "
              f"({report.joined_mid_stream} mid-stream joins)")


if __name__ == "__main__":
    main()
