"""Serving launcher: thin CLI over the paged continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b --smoke

Builds the model, submits a synthetic workload (fixed stagger or Poisson
arrivals), and drives repro.serve.ServeEngine: a paged KV pool (block
tables + prefix caching), chunked prefill interleaved with decode, and one
jit'd step per approximation-policy group. Prints the per-request timeline
and the engine's latency/throughput/KV-utilization report.

``--policy "*/attn/*=exact,*=pc3_tr"`` serves the whole engine with one
per-site policy; ``--tiers "free=*=pc3_tr;paid=*/attn/*=exact"`` registers
named per-request tiers and spreads the workload across them (mixed-tier
traffic batches per resolved policy — no cross-tier recompiles). The legacy
``--variant pc3_tr`` flag still works through the uniform-policy
deprecation shim. After the run the per-group site resolution report is
printed. See benchmarks/serve_bench.py for numbers.
"""
import argparse
import dataclasses
import os
import warnings


def build_daism(variant: str, backend: str):
    from repro.core import Backend, DaismConfig, Variant
    return DaismConfig(variant=Variant(variant), backend=Backend(backend))


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + small workload (CPU-friendly)")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=2,
                   help="decode batch width per policy group")
    p.add_argument("--max-seq", type=int, default=64,
                   help="per-request KV capacity (prompt + generation)")
    p.add_argument("--block-size", type=int, default=16,
                   help="KV page size in tokens (= --max-seq reproduces "
                        "the old slot pool)")
    p.add_argument("--blocks", type=int, default=0,
                   help="physical KV pages (0 = slots*max_seq/block_size, "
                        "the old slot pool's memory)")
    p.add_argument("--prefill-chunk", type=int, default=16,
                   help="prompt tokens ingested per engine tick "
                        "(chunked prefill; power of two)")
    p.add_argument("--prompt-len", type=int, default=8,
                   help="base prompt length (workload staggers around it)")
    p.add_argument("--gen", type=int, default=8,
                   help="base generation length")
    p.add_argument("--arrival-every", type=int, default=0,
                   help="space arrivals N engine steps apart (0 = all at once)")
    p.add_argument("--poisson", type=float, default=0.0,
                   help="Poisson arrival rate in requests/step (overrides "
                        "--arrival-every; 0 = disabled)")
    p.add_argument("--policy", default="",
                   help="engine-wide per-site approximation policy spec, "
                        "e.g. '*/attn/*=exact,*/layer_0/*=exact,*=pc3_tr' "
                        "(repro.policy mini-language)")
    p.add_argument("--tiers", default="",
                   help="named per-request policy tiers, e.g. "
                        "'free=*=pc3_tr;paid=*/attn/*=exact' — the workload "
                        "is spread across them (mixed-tier serving)")
    p.add_argument("--variant", default="exact",
                   help="DEPRECATED (use --policy): uniform multiplier "
                        "variant (exact | fla | ... | pc3_tr)")
    p.add_argument("--backend", default="jnp",
                   help="daism backend for approximate variants")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--devices", type=int, default=0)
    p.add_argument("--shards", type=int, default=1,
                   help="tensor-parallel serving: shard params, KV pages "
                        "and every policy group's step over an N-way "
                        "'model' mesh axis (N must divide --blocks and "
                        "--slots; pair with --devices N on CPU)")
    p.add_argument("--preempt", action="store_true",
                   help="optimistic admission + preemption: swap the "
                        "lowest-priority running request's KV pages to a "
                        "host buffer under pool exhaustion instead of "
                        "reserving whole lifetimes up front")
    p.add_argument("--swap-blocks", type=int, default=0,
                   help="host swap buffer size in KV pages "
                        "(0 = one full request's worth)")
    p.add_argument("--spec-draft", default="",
                   help="self-speculative decoding: draft policy (a --tiers "
                        "name or a raw policy spec) used for cheap draft "
                        "steps; the group's own exact step verifies them "
                        "(greedy outputs stay token-identical)")
    p.add_argument("--spec-k", type=int, default=0,
                   help="draft tokens proposed per speculative verify step "
                        "(0 = speculation off; pair with --spec-draft)")
    p.add_argument("--sync", action="store_true",
                   help="synchronous tick loop (disable the async "
                        "host/device overlap; baseline for "
                        "ServeReport.host_idle_frac)")
    p.add_argument("--no-preflight", action="store_true",
                   help="skip the daism-lint static preflight")
    args = p.parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax

    from repro.configs import get_config
    from repro.models.registry import build_model
    from repro.serve import (EngineConfig, ServeEngine, parse_tiers,
                             poisson_requests, synthetic_requests)

    cfg = get_config(args.arch)
    if args.smoke:
        overrides = {"window": 0}  # paged pools need non-ring caches
        if args.shards > 1:
            # the head-local paged attention shard_map needs kv heads
            # divisible by the mesh axis; the default smoke config has 2
            overrides["kv_heads"] = args.shards
        cfg = cfg.smoke(**overrides)
    if args.policy:
        cfg = cfg.with_policy(args.policy)
    elif args.variant != "exact":
        warnings.warn("--variant/--backend are deprecated; use --policy "
                      f"'*={args.variant}:{args.backend}'", DeprecationWarning,
                      stacklevel=1)
        cfg = dataclasses.replace(cfg,
                                  daism=build_daism(args.variant, args.backend))
    tiers = parse_tiers(args.tiers) if args.tiers else ()
    engine_cfg = EngineConfig(
        num_slots=args.slots, max_seq=args.max_seq,
        block_size=args.block_size, num_blocks=args.blocks,
        prefill_chunk=args.prefill_chunk, tiers=tiers,
        shards=args.shards, preempt=args.preempt,
        swap_blocks=args.swap_blocks, overlap=not args.sync,
        spec_draft=args.spec_draft, spec_k=args.spec_k)
    if not args.no_preflight:
        # static lint of the full (model, policy, engine) triple before the
        # (expensive) params init: bad tiers, window/paged conflicts and
        # undersized pools abort here (launch/lint.py standalone)
        from repro.analyze import preflight

        preflight(cfg, engine_cfg=engine_cfg, label=f"serve {args.arch}")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    mesh = None
    if args.shards > 1:
        if jax.device_count() % args.shards:
            raise SystemExit(
                f"--shards {args.shards} does not divide the "
                f"{jax.device_count()} available devices (on CPU pass "
                f"--devices {args.shards})")
        mesh = jax.make_mesh((args.shards,), ("model",))
    engine = ServeEngine(model, params, engine_cfg, mesh=mesh)
    tier_names = [name for name, _ in tiers]
    if args.poisson > 0:
        requests = poisson_requests(
            args.requests, cfg.vocab, rate=args.poisson,
            base_prompt=args.prompt_len, base_gen=args.gen, seed=args.seed,
            tiers=tier_names)
    else:
        requests = synthetic_requests(
            args.requests, cfg.vocab, base_prompt=args.prompt_len,
            base_gen=args.gen, seed=args.seed,
            arrival_every=args.arrival_every, tiers=tier_names)
    report = engine.run(requests)

    numerics = (f"tiers {args.tiers}" if args.tiers
                else f"policy {args.policy}" if args.policy else args.variant)
    arrivals = (f"poisson rate {args.poisson}" if args.poisson > 0
                else f"every {args.arrival_every}" if args.arrival_every
                else "all at once")
    print(f"== {args.arch} ({numerics}) — {args.requests} requests, "
          f"{args.slots} rows/group, {engine.cfg.blocks} x "
          f"{args.block_size}-token KV pages, arrivals {arrivals} ==")
    for ev in report.events:
        if ev["event"] == "admit":
            joined = " (joined running batch)" if ev["joined_running"] else ""
            cached = (f", {ev['cached_blocks']} cached"
                      if ev.get("cached_blocks") else "")
            print(f"step {ev['step']:4d}  admit  req {ev['request_id']} "
                  f"-> {ev['group']}/row {ev['slot']} "
                  f"[{ev['blocks']} pages{cached}]{joined}")
        elif ev["event"] == "preempt":
            print(f"step {ev['step']:4d}  preempt req {ev['request_id']} "
                  f"({ev['group']}/row {ev['slot']}: {ev['blocks']} pages "
                  "swapped to host)")
        elif ev["event"] == "resume":
            print(f"step {ev['step']:4d}  resume req {ev['request_id']} "
                  f"-> {ev['group']}/row {ev['slot']} "
                  f"[{ev['blocks']} pages restored]")
        else:
            print(f"step {ev['step']:4d}  retire req {ev['request_id']} "
                  f"({ev['group']}/row {ev['slot']} freed, {ev['reason']})")
    print(report.summary())
    if args.tiers or args.policy or args.variant != "exact":
        print(engine.resolution_report())
    if report.completed:
        sample = report.completed[0]
        print(f"sample (req {sample.request_id}): {sample.output}")
    default_workload = all(
        getattr(args, k) == p.get_default(k)
        for k in ("requests", "slots", "gen", "prompt_len", "arrival_every",
                  "poisson", "block_size", "blocks", "prefill_chunk"))
    if args.smoke and default_workload:
        # the gate is calibrated to the default smoke workload (staggered
        # lengths oversubscribing 2 rows); custom shapes — one row, spaced
        # arrivals, equal-length retire waves — may legitimately never join
        if report.joined_mid_stream < 2:  # explicit: survives python -O
            raise SystemExit(
                "smoke workload must exercise continuous batching "
                f"(got {report.joined_mid_stream} mid-stream joins)")
        print("SMOKE-OK: continuous batching exercised "
              f"({report.joined_mid_stream} mid-stream joins)")
    if args.smoke and args.tiers and report.policy_groups < 2:
        raise SystemExit(
            "smoke --tiers workload must exercise >= 2 policy groups "
            f"(got {report.policy_groups})")
    if args.smoke and args.tiers:
        print(f"SMOKE-OK: {report.policy_groups} policy groups served "
              "mixed-tier traffic")
    if args.smoke and args.shards > 1:
        if report.shards != args.shards:
            raise SystemExit(
                f"smoke --shards {args.shards} ran on {report.shards} "
                "shard(s)")
        print(f"SMOKE-OK: served tensor-parallel over {report.shards} "
              "shards")
    if args.smoke and args.preempt and args.blocks:
        # an explicitly undersized pool (--blocks) must actually exercise
        # the swap path; auto-sized pools never exhaust
        if not (report.preemptions and report.resumes):
            raise SystemExit(
                "smoke --preempt with a constrained pool must preempt and "
                f"resume (got {report.preemptions} preemption(s), "
                f"{report.resumes} resume(s))")
        if any(s.finish_reason not in ("eos", "length")
               for s in report.completed):
            raise SystemExit("smoke --preempt: a request finished abnormally")
        print(f"SMOKE-OK: {report.preemptions} preemption(s) / "
              f"{report.resumes} resume(s) under page exhaustion")
    if args.smoke and args.spec_k:
        if not report.spec_steps:
            raise SystemExit(
                "smoke --spec-k workload never took a speculative verify "
                "step (draft group ineligible or controller disabled it "
                "before the first step)")
        if report.spec_tokens_per_step < 1.0:
            raise SystemExit(
                "smoke --spec-k: tokens per verify step "
                f"{report.spec_tokens_per_step:.2f} < 1.0 — the bonus-token "
                "guarantee is broken")
        print(f"SMOKE-OK: speculative decoding took {report.spec_steps} "
              f"verify step(s), accept rate {report.spec_accept_rate:.2f}, "
              f"{report.spec_tokens_per_step:.2f} tokens/step")


if __name__ == "__main__":
    main()
