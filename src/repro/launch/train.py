"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --smoke --steps 200 --batch 8 --seq 64 --ckpt /tmp/ckpt

On a real fleet this process runs per host under jax.distributed with the
production mesh (launch/mesh.py); in this container it drives the same code
path on however many local devices exist (--devices N forces fake devices,
set BEFORE jax init). Fault tolerance: re-running the same command resumes
from the newest intact checkpoint (runtime/fault_tolerance.py).
"""
import argparse
import os
import sys


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (CPU-trainable)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--devices", type=int, default=0,
                   help="force N fake host devices (must be first jax use)")
    p.add_argument("--mesh", default="auto",
                   help="'auto' | 'DATAxMODEL' e.g. 4x2")
    p.add_argument("--daism", default="exact",
                   help="DEPRECATED (use --policy): uniform multiplier "
                        "variant for parameter GEMMs "
                        "(exact|fla|hla|pc2|pc3|pc2_tr|pc3_tr)")
    p.add_argument("--policy", default="",
                   help="per-site approximation policy spec, e.g. "
                        "'*/layer_0/*=exact,@lm_head=exact,*=pc3_tr'")
    p.add_argument("--no-preflight", action="store_true",
                   help="skip the daism-lint static preflight")
    args = p.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count={args.devices}")
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core.config import Backend, DaismConfig, Variant
    from repro.data.synthetic import lm_batches, shard_batch
    from repro.launch.mesh import best_effort_mesh, make_mesh
    from repro.launch.steps import build_artifacts
    from repro.optim import AdamWConfig
    from repro.runtime.fault_tolerance import TrainLoopConfig, run

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.policy:
        cfg = cfg.with_policy(args.policy)
    elif args.daism != "exact":
        import warnings

        warnings.warn("--daism is deprecated; use --policy "
                      f"'*={args.daism}'", DeprecationWarning, stacklevel=1)
        cfg = dataclasses.replace(
            cfg, daism=DaismConfig(variant=Variant(args.daism),
                                   backend=Backend.JNP))
    if not args.no_preflight:
        # static lint of the (model, policy) pair before any compilation:
        # zero-match rules, illegal backends, scan shatter all fail here
        # in O(seconds) instead of mid-trace (launch/lint.py standalone)
        from repro.analyze import preflight

        preflight(cfg, serving=False, label=f"train {args.arch}")
    if args.mesh == "auto":
        mesh = best_effort_mesh(model_parallel=1 if jax.device_count() == 1
                                else 2)
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    art = build_artifacts(cfg, mesh, opt_cfg=AdamWConfig(lr=args.lr),
                          total_steps=args.steps,
                          warmup=max(args.steps // 20, 1))
    params = art.init_params(jax.random.PRNGKey(0))
    opt = art.init_opt(params)
    gen = lm_batches(cfg.vocab, args.batch, args.seq, seed=0)
    bsh = art.batch_sharding(next(gen))

    def put(b):
        return shard_batch(b, bsh)

    def log(step, m):
        print(f"step {step:5d} loss {float(m['loss']):.4f} "
              f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e}",
              flush=True)

    loop = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                           ckpt_every=args.ckpt_every, log_every=10)
    params, opt, state = run(loop, art.train_step, params, opt, gen, put,
                             metrics_hook=log,
                             param_shardings=art.param_shardings,
                             opt_shardings=art.opt_shardings)
    print(f"done at step {state.step}; stragglers seen: {state.stragglers}")
    if args.policy or args.daism != "exact":
        from repro.policy import site_report

        print(site_report(cfg.approx_policy))


if __name__ == "__main__":
    main()
