"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS *before* the first jax device query, while smoke
tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests / elastic re-mesh use this)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def best_effort_mesh(model_parallel: int = 1):
    """Elastic helper: build the largest (data, model) mesh the *currently
    alive* devices support. Used by the fault-tolerant driver when restarting
    after losing hosts: model_parallel is fixed by the checkpoint layout, the
    data axis absorbs whatever is left."""
    n = jax.device_count()
    if n % model_parallel != 0:
        raise ValueError(f"{n} devices not divisible by TP={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
