from .adamw import AdamWConfig, AdamWState, abstract_state, apply_updates, init_state, state_axes
from .schedule import cosine_with_warmup, linear_warmup
from .grad_compress import compressed_psum, quantize_int8

__all__ = ["AdamWConfig", "AdamWState", "abstract_state", "apply_updates",
           "init_state", "state_axes", "cosine_with_warmup", "linear_warmup",
           "compressed_psum", "quantize_int8"]
