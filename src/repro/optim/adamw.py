"""AdamW with f32 master weights, built for FSDP-sharded optimizer state.

No optax in this container; the implementation is deliberately explicit so
the optimizer state pytree (master, m, v) inherits the parameters' logical
axes — the launcher shards it with the same FSDP rules (ZeRO-style), which
is what makes 340B trainable on 512 chips (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray        # ()
    master: Any              # f32 copy of params
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_state(params) -> AdamWState:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), master, zeros,
                      jax.tree.map(jnp.copy, zeros))


def abstract_state(params) -> AdamWState:
    """ShapeDtypeStruct version (dry-run: no allocation)."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                      jax.tree.map(f32, params), jax.tree.map(f32, params),
                      jax.tree.map(f32, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig,
                  lr_scale: jnp.ndarray = 1.0
                  ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new bf16 params, new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, mast, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        new = mast - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * mast)
        return new, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mast = jax.tree.leaves(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, ma, m, v) for g, ma, m, v in
           zip(flat_g, flat_mast, flat_m, flat_v)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
    return new_params, AdamWState(step, new_master, new_m, new_v), metrics


def state_axes(param_axes_tree) -> AdamWState:
    """Logical axes for the optimizer state (same as params, FSDP-sharded)."""
    return AdamWState((), param_axes_tree,
                      param_axes_tree, param_axes_tree)
