"""Gradient compression for the data-parallel all-reduce.

At 1000+ nodes the gradient all-reduce dominates the collective term for
small models (EXPERIMENTS.md §Roofline). Two honest, HLO-visible modes:

* ``bf16``: cast gradients to bf16 before the psum — exactly 2x less
  all-reduce traffic than f32, loss-free in practice for gradients that are
  consumed by Adam normalization.
* ``int8``: two-phase — (1) pmax the per-leaf scale across replicas,
  (2) quantize with the *global* scale and psum the int8 payload widened to
  int32 for overflow-safe accumulation. The on-wire format is whatever the
  backend emits for the psum operand; we do not claim a 4x wire win blindly —
  the roofline harness parses the actual collective operand bytes from the
  compiled HLO, so the measured collective term reflects reality.

Quantization error is zero-mean and <1 % cosine distortion on Adam-scale
gradients (tests/test_optim.py).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8)


def compressed_psum(grads: Any, axis_names, mode: str = "int8") -> Any:
    """Mean-reduce a gradient pytree across ``axis_names`` with compression.

    Must be called inside a shard_map/pmap context where the axes are bound
    (use ``repro.compat.shard_map``, which resolves the right jax API).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    n = 1
    for a in axis_names:
        n = n * lax.psum(1, a)

    def psum_all(x):
        for a in axis_names:
            x = lax.psum(x, a)
        return x

    def one(g):
        if mode == "none":
            return psum_all(g.astype(jnp.float32)) / n
        if mode == "bf16":
            return (psum_all(g.astype(jnp.bfloat16)).astype(jnp.float32) / n
                    ).astype(g.dtype)
        # int8: global scale first (tiny scalar all-reduce), then payload.
        s = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
        for a in axis_names:
            s = lax.pmax(s, a)
        q = quantize_int8(g, s).astype(jnp.int32)
        total = psum_all(q)
        return (total.astype(jnp.float32) * s / n).astype(g.dtype)

    return jax.tree.map(one, grads)
