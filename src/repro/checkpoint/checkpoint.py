"""Checkpointing: atomic, resumable, reshardable.

Format: one directory per step, ``step_<N>/``, containing

  * ``arrays.npz``   — every leaf as a *full logical array* (gathered), keyed
                       by its flattened pytree path;
  * ``meta.json``    — step number, tree structure manifest, digests;
  * ``_COMPLETE``    — sentinel written last (atomic-rename discipline: a
                       crash mid-write leaves no sentinel, and the loader
                       skips incomplete directories).

Storing full logical arrays makes restore *elastic*: loading onto a
different mesh is just a different device_put spec (the fault-tolerant
driver exploits this after losing hosts). For multi-TB models a per-shard
format would replace ``np.savez`` — the API (save/restore/latest_step) and
atomicity protocol are the deliverable here, and tests exercise
crash-resume and mesh-change restore end to end.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

SENTINEL = "_COMPLETE"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomically persist ``tree`` (gathers to host)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        flat = _flatten(tree)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        digest = hashlib.sha256()
        for k in sorted(arrays):
            digest.update(k.encode())
            digest.update(arrays[k].tobytes()[:4096])
        meta = {"step": step, "keys": sorted(arrays.keys()),
                "digest": digest.hexdigest()}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, SENTINEL), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, SENTINEL)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like``; device_put with ``shardings``
    (which may target a *different* mesh than the one that saved — elastic)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, SENTINEL)):
        raise FileNotFoundError(f"incomplete/missing checkpoint {path}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat_sh = _flatten(shardings) if shardings is not None else None

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    out = []
    for key, leaf in zip(keys, leaves_like):
        arr = data[key]
        want = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else arr.dtype
        if arr.dtype.kind == "V":  # ml_dtypes (bf16) round-trip via npz
            arr = arr.view(want)
        else:
            arr = arr.astype(want, copy=False)
        if flat_sh is not None:
            out.append(jax.device_put(arr, flat_sh[key]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
