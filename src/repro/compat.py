"""Version-compatibility shims for the pinned jax.

``jax.shard_map`` is a top-level API only on newer jax; the pinned 0.4.x
exposes it as ``jax.experimental.shard_map.shard_map`` and spells the
replication-check kwarg ``check_rep`` instead of ``check_vma``. Every
shard_map call site in the repo routes through :func:`shard_map` below so
the distributed stack (MoE expert parallelism, pipeline parallelism,
compressed psum) runs on both spellings.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    """``jax.shard_map`` with the modern keyword spelling on any jax.

    ``check_vma`` (new spelling) is translated to ``check_rep`` where the
    pinned jax still uses the old name; all other kwargs pass through.
    """
    if check_vma is not None:
        kw[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
