"""VGG-16 variation D with 2 FC layers (paper section 5.1.1), CIFAR10."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="vgg16", family="cnn",
    n_layers=16, d_model=0, n_heads=0, kv_heads=0, head_dim=0, d_ff=0,
    vocab=10, param_dtype="float32", compute_dtype="float32",
)
