"""Assigned-architecture configs + the paper's own models.

Every module exposes ``CONFIG`` (exact assigned numbers) and the registry
maps ``--arch <id>`` to it. ``input_specs(cfg, shape_name)`` builds the
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

ARCH_IDS = (
    "tinyllama_1_1b",
    "gemma_2b",
    "starcoder2_15b",
    "nemotron_4_340b",
    "dbrx_132b",
    "qwen3_moe_235b",
    "llama_3_2_vision_11b",
    "xlstm_1_3b",
    "whisper_large_v3",
    "zamba2_1_2b",
)

PAPER_IDS = ("lenet5", "vgg16", "vgg8")

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS + PAPER_IDS}


def get_config(arch: str) -> ArchConfig:
    arch = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


# ---------------------------------------------------------------------------
# Assigned input shapes (LM shapes are seq_len x global_batch)
# ---------------------------------------------------------------------------

SHAPES: Dict[str, Tuple[int, int, str]] = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# Mandated skips (DESIGN.md §4): long_500k only for SSM/hybrid.
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def cell_enabled(cfg: ArchConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.family in _LONG_OK_FAMILIES
    return True


def input_specs(cfg: ArchConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    Returns (batch_dict, kind). Decode kinds also need the cache, built
    abstractly by the model's ``init_cache(..., abstract=True)``.
    """
    seq, batch, kind = SHAPES[shape_name]
    i32 = jnp.int32
    specs = {}
    if kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    else:  # decode: one new token against a cache of length seq
        specs["tokens"] = jax.ShapeDtypeStruct((batch, 1), i32)
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return specs, kind
