"""Gemma-2B [arXiv:2403.08295]: GeGLU, head_dim=256, MQA (kv=1)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="geglu", norm="rmsnorm",
    rope_theta=10000.0, tie_embeddings=True,
)
