"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision]: cross-attn
image layers every 5 blocks; vision frontend is a stub (precomputed patch
embeddings via input_specs, per the assignment)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, act="swiglu", norm="rmsnorm",
    rope_theta=500000.0,
    cross_every=5, n_image_tokens=1601,
)
