"""xLSTM-1.3B [arXiv:2405.04517]: sLSTM + mLSTM blocks (xLSTM[7:1]),
attention-free => runs long_500k."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304, act="gelu", norm="rmsnorm",
    rope_theta=0.0,
    slstm_every=8,
)
