"""VGG-8 (paper section 5.3 architecture evaluation)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="vgg8", family="cnn",
    n_layers=8, d_model=0, n_heads=0, kv_heads=0, head_dim=0, d_ff=0,
    vocab=10, param_dtype="float32", compute_dtype="float32",
)
