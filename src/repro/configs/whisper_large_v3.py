"""Whisper-large-v3 [arXiv:2212.04356]: enc-dec, conv/audio frontend is a
stub (precomputed frame embeddings). 32 enc + 32 dec layers, MHA (kv=20)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866, act="gelu", norm="layernorm",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions
    enc_layers=32, enc_frames=1500,
)
