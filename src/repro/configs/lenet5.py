"""LeNet-5 (paper section 5.1): 2 conv (5x5) + 3 FC, MNIST."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="lenet5", family="cnn",
    n_layers=5, d_model=0, n_heads=0, kv_heads=0, head_dim=0, d_ff=0,
    vocab=10, param_dtype="float32", compute_dtype="float32",
)
