"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + shared attention block.
ssm_state=64; hybrid => runs long_500k (shared attn uses a 4k sliding
window at long context, DESIGN.md section 4)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000, act="swiglu", norm="rmsnorm",
    rope_theta=10000.0,
    ssm_state=64, d_inner=4096, ssm_heads=64, conv_kernel=4,
    shared_attn_every=6, window=4096,
)
