"""DBRX-132B [hf:databricks/dbrx-base]: MoE 16 experts top-4, GQA kv=8."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352, act="swiglu", norm="rmsnorm",
    rope_theta=500000.0,
    n_experts=16, topk=4, expert_ff=10752,
)
