"""StarCoder2-15B [arXiv:2402.19173]: GQA kv=4, RoPE, LayerNorm."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152, act="gelu", norm="layernorm",
    rope_theta=100000.0,
)
