"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family]: 128 experts top-8."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, act="swiglu", norm="rmsnorm",
    rope_theta=1000000.0,
    n_experts=128, topk=8, expert_ff=1536,
)
