"""Nemotron-4-340B [arXiv:2402.16819]: GQA kv=8, squared-ReLU MLP."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, kv_heads=8, head_dim=192,
    d_ff=73728, vocab=256000, act="relu2", norm="layernorm",
    rope_theta=10000.0,
)
