"""Step-time EWMA straggler watchdog.

Shared by the fault-tolerant train loop (runtime/fault_tolerance.py) and
the serving engine (repro/serve): both run synchronous step loops where a
slow host (or a surprise recompile) stretches every step, and both want
the same detection rule — flag steps slower than ``factor`` x the running
mean, excluding compile-dominated warmup steps from the estimate.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StepWatchdog:
    factor: float = 3.0      # straggler threshold vs. the EWMA
    alpha: float = 0.1       # EWMA smoothing
    warmup: int = 1          # leading steps excluded (compile-dominated)

    ewma: float = 0.0
    stragglers: int = 0
    observed: int = 0

    def observe(self, dt: float) -> bool:
        """Feed one step time (seconds). Returns True if it is a straggler.

        The first ``warmup`` steps are excluded entirely — a 10-100x
        compile step would otherwise poison the EWMA and mask real
        stragglers for many steps.
        """
        self.observed += 1
        if self.observed <= self.warmup:
            return False
        if self.ewma == 0.0:
            self.ewma = dt
            return False
        slow = dt > self.factor * self.ewma
        if slow:
            self.stragglers += 1
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow
