"""Fault-tolerant training driver: checkpoint/restart, straggler watchdog,
simulated pre-emption, elastic re-mesh.

On a real 1000+-node fleet, failures arrive as (a) whole-process death
(pre-emption / hardware), (b) stragglers (a slow host stretching every
synchronous step), (c) shrunk capacity after restart. The driver handles:

  (a) every step runs inside the resume loop: on crash, the process (or its
      replacement) calls ``run()`` again and resumes from the newest intact
      checkpoint (atomic-sentinel protocol in checkpoint.py). Tests inject
      ``SimulatedPreemption`` mid-run and assert bit-identical continuation.
  (b) a step-time EWMA watchdog flags steps slower than
      ``straggler_factor`` x the running mean — on a real fleet this feeds
      the scheduler (drain + replace host); here it logs and counts, and the
      hook is exposed for tests.
  (c) ``best_effort_mesh`` + full-logical-array checkpoints make restore
      onto fewer hosts a pure resharding (elastic data axis).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.runtime.watchdog import StepWatchdog

log = logging.getLogger("repro.runtime")


class SimulatedPreemption(RuntimeError):
    """Raised by tests to model a host loss at an arbitrary step."""


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    log_every: int = 10


@dataclasses.dataclass
class LoopState:
    step: int = 0
    ewma_step_time: float = 0.0
    stragglers: int = 0
    measured_steps: int = 0  # steps contributing to the EWMA (skips warmup)


def run(loop_cfg: TrainLoopConfig,
        train_step: Callable,
        params: Any, opt_state: Any,
        batches: Iterator[Dict],
        put_batch: Callable[[Dict], Dict],
        *,
        fault_hook: Optional[Callable[[int], None]] = None,
        metrics_hook: Optional[Callable[[int, Dict], None]] = None,
        param_shardings: Any = None,
        opt_shardings: Any = None):
    """Resumable training loop. Returns (params, opt_state, LoopState).

    On entry, if a checkpoint exists in ``ckpt_dir`` the passed-in
    params/opt_state are REPLACED by the restored ones (restart semantics).
    ``fault_hook(step)`` is called before each step (tests raise
    SimulatedPreemption from it).
    """
    state = LoopState()
    watchdog = StepWatchdog(factor=loop_cfg.straggler_factor,
                            alpha=loop_cfg.ewma_alpha)
    last = ckpt.latest_step(loop_cfg.ckpt_dir)
    if last is not None:  # restart semantics: joint {"params","opt"} layout
        log.warning("resuming from checkpoint step %d", last)
        tree = ckpt.restore(loop_cfg.ckpt_dir, last,
                            {"params": params, "opt": opt_state},
                            {"params": param_shardings, "opt": opt_shardings}
                            if param_shardings is not None else None)
        params, opt_state = tree["params"], tree["opt"]
        state.step = last

    while state.step < loop_cfg.total_steps:
        if fault_hook is not None:
            fault_hook(state.step)
        batch = put_batch(next(batches))
        t0 = time.monotonic()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.monotonic() - t0
        # straggler watchdog (shared with the serving engine; see
        # runtime/watchdog.py for the warmup-exclusion rationale)
        ewma_before = watchdog.ewma  # observe() folds dt in; log the baseline
        if watchdog.observe(dt):
            log.warning("straggler step %d: %.3fs vs EWMA %.3fs",
                        state.step, dt, ewma_before)
        state.measured_steps = watchdog.observed
        state.ewma_step_time = watchdog.ewma
        state.stragglers = watchdog.stragglers
        state.step += 1
        if metrics_hook is not None and state.step % loop_cfg.log_every == 0:
            metrics_hook(state.step, jax.device_get(metrics))
        if state.step % loop_cfg.ckpt_every == 0 or \
                state.step == loop_cfg.total_steps:
            ckpt.save(loop_cfg.ckpt_dir, state.step,
                      {"params": params, "opt": opt_state})
            ckpt.cleanup(loop_cfg.ckpt_dir, loop_cfg.keep)
    return params, opt_state, state
