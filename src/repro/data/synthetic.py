"""Synthetic data pipelines (offline container: no MNIST/CIFAR downloads).

Two generators with *learnable structure* (so end-to-end training drivers
show real loss curves, and the paper's Table-2 experiment can measure
accuracy degradation under approximate numerics):

* ``lm_batches`` — token streams from a fixed random bigram automaton with
  copy motifs: a model that learns the transition table reaches much lower
  loss than unigram entropy.
* ``image_batches`` — class-template images (one fixed random template per
  class) + Gaussian noise + random shifts: linearly separable-ish, CNN
  reaches >95 % quickly at low noise; accuracy deltas across multiplier
  variants mirror the paper's Table 2 protocol.

Both are host-side numpy generators; ``shard_batch`` device_puts onto the
mesh with the batch sharding (data-parallel ingestion: each host slice would
feed its local devices in a real multi-host run).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    # sparse bigram automaton: each token has 4 likely successors
    succ = rng.integers(0, vocab, (vocab, 4))
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        for t in range(seq):
            choice = succ[toks[:, t], rng.integers(0, 4, batch)]
            noise = rng.integers(0, vocab, batch)
            use_noise = rng.random(batch) < 0.1
            toks[:, t + 1] = np.where(use_noise, noise, choice)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def image_batches(n_classes: int, batch: int, *, shape=(28, 28, 1),
                  noise: float = 0.35, seed: int = 0,
                  template_seed: int = 1234, max_shift: int = 0,
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """``seed`` drives sampling; ``template_seed`` fixes the class identity
    so train/eval splits with different sampling seeds share the task.
    ``max_shift``: circular-shift augmentation — note white-noise templates
    decorrelate under shifts, so >0 makes the task drastically harder."""
    rng = np.random.default_rng(seed)
    # smooth (low-res-upsampled) templates: local 3x3 patches carry class
    # signal, matching the inductive bias of convnets (white-noise templates
    # have ~no local structure and starve early conv layers of SNR)
    trng = np.random.default_rng(template_seed)
    h, w, c = shape
    f = max(h // 8, 1)
    low = trng.normal(size=(n_classes, -(-h // f), -(-w // f), c))
    templates = np.kron(low, np.ones((1, f, f, 1))).astype(np.float32)
    templates = templates[:, :h, :w, :c]
    templates /= np.linalg.norm(
        templates.reshape(n_classes, -1), axis=1).reshape(
        (n_classes,) + (1,) * len(shape))
    templates *= 8.0
    while True:
        labels = rng.integers(0, n_classes, batch)
        imgs = templates[labels] + rng.normal(
            size=(batch,) + shape).astype(np.float32) * noise
        if max_shift:  # circular-shift augmentation (see docstring)
            sx, sy = rng.integers(-max_shift, max_shift + 1, 2)
            imgs = np.roll(imgs, (sx, sy), axis=(1, 2))
        yield {"images": imgs.astype(np.float32), "labels": labels.astype(np.int32)}


def eval_set(gen: Iterator, n_batches: int):
    return [next(gen) for _ in range(n_batches)]


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict:
    """device_put a host batch with the step's batch shardings."""
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), dict(batch),
        jax.tree.map(lambda s: s, shardings))
