"""Per-site approximation policies for DAISM numerics.

Instead of threading one global ``DaismConfig`` through every layer, models
name each contraction they perform (an *op-site*, e.g.
``decoder/layer_3/attn/wq``) and resolve its numerics through an injectable
:class:`ApproxPolicy` — an ordered list of glob rules mapping sites to
:class:`~repro.core.config.DaismConfig` values. Different layers (or op
kinds) can therefore run different multiplier variants in one forward pass,
which is the paper's energy/accuracy trade-off made addressable.

Quick start::

    from repro import policy as P

    # attention exact, first/last layer exact, middle layers PC3_tr:
    pol = P.parse_policy("*/attn/*=exact,*/layer_0/*=exact,"
                         "*/layer_21/*=exact,*=pc3_tr")
    cfg = dataclasses.replace(get_config("tinyllama_1_1b"), policy=pol)
    model = build_model(cfg)           # consumes the policy internally
    logits, _ = model.forward(params, batch)
    print(P.site_report(pol))          # per-site resolution + energy table

Public API
----------

``ApproxPolicy``
    Frozen, hashable rule list (jit-static). Constructors:
    ``uniform``, ``first_last_exact``, ``attention_exact``,
    ``depth_schedule``.
``Rule``
    One ``pattern -> DaismConfig`` entry; ``@kind`` patterns match the
    :class:`OpKind` instead of the path.
``parse_policy(spec)`` / ``parse_config(spec)``
    CLI mini-language: ``"*/attn/*=exact,*=pc3_tr:jnp"``.
``OpKind`` / ``site_scope`` / ``current_path``
    The op-site abstraction (see :mod:`repro.policy.sites`).
``make_dot(policy)`` / ``policy_dot`` / ``policy_conv2d`` /
``policy_expert_matmul``
    Injection points: ``dot_general``-style callables models consume.
``resolve_site`` / ``validate_for_dtype`` / ``auto_interpret``
    The backend dispatcher (dtype validation at resolve time).
``site_report`` / ``resolution_log`` / ``estimated_energy_uj`` /
``kernel_stats`` / ``clear_log``
    Trace-time resolution reporting and kernel-cache introspection.
``plan_segments(policy, sites_fn, lo, hi)``
    Split a layer range into maximal runs of identical resolved configs so
    scanned layer stacks stay O(1) in HLO while honoring per-depth rules.
"""
from __future__ import annotations

from .dispatch import (SiteEvent, attention_kernel, auto_interpret,
                       clear_log, effective_attn_config, energy_per_mult_pj,
                       estimated_energy_uj, kernel_stats, make_dot,
                       matmul_kernel, observe_sites, policy_conv2d,
                       policy_dot, policy_expert_matmul, resolution_log,
                       resolve_site, site_report, validate_for_dtype)
from .policy import (EXACT, ApproxPolicy, Rule, describe_config,
                     layer_signature, parse_config, parse_policy,
                     plan_segments)
from .sites import OpKind, current_path, current_prefix, site_scope

__all__ = [
    "ApproxPolicy", "Rule", "OpKind", "EXACT",
    "parse_policy", "parse_config", "describe_config",
    "site_scope", "current_path", "current_prefix",
    "make_dot", "policy_dot", "policy_conv2d", "policy_expert_matmul",
    "resolve_site", "validate_for_dtype", "auto_interpret",
    "site_report", "resolution_log", "estimated_energy_uj",
    "kernel_stats", "clear_log", "matmul_kernel",
    "attention_kernel", "effective_attn_config",
    "plan_segments", "layer_signature",
    "observe_sites", "SiteEvent", "energy_per_mult_pj",
]
