"""Op-site abstraction: stable names for every approximate contraction.

Every matmul a model executes gets a *site*: a stable, human-readable path
like ``decoder/layer_3/attn/wq`` plus an :class:`OpKind`. Policies
(:mod:`repro.policy.policy`) map sites to :class:`~repro.core.config.DaismConfig`
numerics, so per-layer / per-op approximation levels become addressable
instead of one global knob.

Paths are built from a trace-time scope stack:

* :meth:`repro.models.module.Ctx.scope` pushes its scope names (``attn``,
  ``ffn``, ``mamba``, ...) automatically, so site paths mirror parameter
  paths;
* models push structural prefixes (``decoder``, ``layer_3``, ``cross_0``)
  with :func:`site_scope` around their layer stacks.

The stack is read while jax *traces* a model function; traced programs bake
the resolved numerics in, so replays (jit cache hits, remat, scan) reuse the
resolution made at trace time. Layer scans share one trace across the layers
they cover, which is why models split their scans into segments of uniform
resolved config (:func:`repro.policy.policy.plan_segments`) and label each
segment with its first layer index.
"""
from __future__ import annotations

import contextlib
import enum
import threading
from typing import Iterator, Tuple


class OpKind(str, enum.Enum):
    """What kind of contraction a site performs (coarse classes for rules)."""

    DENSE = "dense"            # parameter GEMM (projections, FC, MLP)
    CONV = "conv"              # convolution lowered to im2col GEMM
    ATTN_QK = "attn_qk"        # dynamic attention GEMMs (qk^T + att@v); exact
    #                            unless the rule opts into ':flash' dispatch
    MOE_EXPERT = "moe_expert"  # batched expert GEMM inside an MoE FFN
    LM_HEAD = "lm_head"        # unembedding / classifier head


_LOCAL = threading.local()


def _stack() -> list:
    st = getattr(_LOCAL, "stack", None)
    if st is None:
        st = _LOCAL.stack = []
    return st


@contextlib.contextmanager
def site_scope(name: str, *, repeat: int = 1) -> Iterator[None]:
    """Push ``name`` onto the site-path stack for the duration of the block.

    ``repeat`` declares how many times the traced region executes per model
    step (a scan segment of N layers traces once but runs N times); the
    dispatcher scales its per-site multiply counts by the ambient repeat
    product so energy estimates stay honest.
    """
    st = _stack()
    st.append((str(name), int(repeat)))
    try:
        yield
    finally:
        st.pop()


def current_path(leaf: str = "") -> str:
    """The site path at this point of the trace, optionally with a leaf name."""
    parts = [name for name, _ in _stack()] + ([str(leaf)] if leaf else [])
    return "/".join(parts)


def current_repeat() -> int:
    """Product of ambient ``repeat`` declarations (trace multiplicity)."""
    out = 1
    for _, r in _stack():
        out *= r
    return out


def current_prefix() -> Tuple[str, ...]:
    """The current scope stack as a tuple (for tests / debugging)."""
    return tuple(name for name, _ in _stack())
