"""Backend dispatcher: resolve a site's numerics, validate, execute.

This is the single injection point between models and the DAISM GEMM:

* :func:`make_dot` builds a ``dot``-style callable bound to one policy
  (AQT-style): models call ``dot(x, w, name=..., kind=...)`` instead of
  branching on a threaded config.
* Resolution happens at trace time: the site path comes from the ambient
  :mod:`~repro.policy.sites` scope stack, backend/dtype combinations are
  validated here (actionable errors naming the site), and the decision is
  recorded in a per-policy resolution log for reporting.
* Jitted kernels are cached per distinct resolved :class:`DaismConfig`
  (:func:`matmul_kernel`), so a mixed policy re-uses one compiled kernel per
  unique config instead of recompiling per call site.
* :func:`auto_interpret` is the one home for Pallas interpret auto-selection
  (kernels/ops.py consumes it).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import Backend, DaismConfig, Variant

from .policy import EXACT, ApproxPolicy, describe_config
from .sites import OpKind, current_path, current_repeat

# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

_GEMM_DTYPES = ("bfloat16", "float32")


def auto_interpret(cfg: "DaismConfig | bool | None" = None) -> bool:
    """Pallas interpret mode: explicit setting wins, else True off-TPU.

    The one home for interpret auto-selection: accepts a full
    :class:`DaismConfig` (its ``interpret`` field is the explicit setting)
    or the bare explicit flag, so direct kernel entry points
    (``kernels.daism_matmul`` / ``kernels.flash_attention``) resolve their
    ``interpret=None`` defaults through the same explicit-wins/TPU-compiles
    semantics as the policy dispatcher.
    """
    explicit = cfg.interpret if isinstance(cfg, DaismConfig) else cfg
    if explicit is not None:
        return explicit
    return jax.default_backend() == "cpu"


def effective_attn_config(cfg: DaismConfig, *,
                          eligible: bool = True) -> DaismConfig:
    """The config an attention-score site (OpKind.ATTN_QK) actually runs.

    Attention numerics follow the resolved config only when it opts into the
    fused flash kernel (``attn_kernel='flash'``) *and* the call shape is
    flash-eligible; otherwise the site executes the exact jnp online-softmax
    path, so its effective config is EXACT. This keeps catch-all rules like
    ``*=pc3_tr`` from silently changing attention numerics (or the energy
    report) the moment the ATTN_QK site exists — approximating the dynamic
    attention GEMMs is strictly opt-in via the ``:flash`` spec token.
    """
    if cfg.attn_kernel == "flash" and eligible:
        return cfg
    return EXACT


def validate_for_dtype(cfg: DaismConfig, dtype, *, site: str = "") -> None:
    """Raise an actionable error if ``cfg`` cannot run on ``dtype`` operands.

    Called at resolve time (and by ``ArchConfig`` at construction via its
    compute dtype) so misconfigurations fail before any kernel traces.
    """
    if cfg.exact:
        return
    where = f"site {site!r}: " if site else ""
    name = jnp.dtype(dtype).name
    if name not in _GEMM_DTYPES:
        raise ValueError(
            f"{where}DAISM approximate GEMMs support bfloat16/float32 "
            f"operands, got {name}; run this site exact or change the "
            "compute dtype")
    if cfg.backend in (Backend.LUT, Backend.PALLAS) and name != "bfloat16":
        raise ValueError(
            f"{where}backend {cfg.backend.value!r} is bfloat16-only "
            f"(256x256 mantissa table / Pallas kernel), got {name}; use "
            "backend='jnp' for float32 or switch the compute dtype to "
            "bfloat16")


# ---------------------------------------------------------------------------
# Resolution log (per-policy, per-site) — feeds the reports
# ---------------------------------------------------------------------------

# policy -> {(path, kind): (config, dtype_name, macs_per_trace)}
_LOG: Dict[ApproxPolicy, Dict[Tuple[str, OpKind],
                              Tuple[DaismConfig, str, int]]] = {}
_STATS = {"kernel_builds": 0, "kernel_traces": 0}


def clear_log(policy: Optional[ApproxPolicy] = None) -> None:
    if policy is None:
        _LOG.clear()
    else:
        _LOG.pop(policy, None)


def resolution_log(policy: ApproxPolicy) -> Dict[Tuple[str, OpKind],
                                                 Tuple[DaismConfig, str, int]]:
    """Sites resolved so far for ``policy`` (only traced sites appear)."""
    return dict(_LOG.get(policy, {}))


def _record(policy: ApproxPolicy, path: str, kind: OpKind, cfg: DaismConfig,
            dtype, macs: int) -> None:
    _LOG.setdefault(policy, {})[(path, kind)] = (
        cfg, jnp.dtype(dtype).name, int(macs))


# ---------------------------------------------------------------------------
# Site observers — the static analyzer's trace hook
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SiteEvent:
    """One resolved contraction site, as seen at trace time.

    ``dims = (m, k, n)`` are the dims of a single kernel invocation (leading
    batch axes folded into ``m``; for batched expert GEMMs the per-expert
    dims). ``macs`` is the total multiply count of the site including any
    expert batching and the ambient scan ``repeat``.
    """

    path: str
    kind: OpKind
    config: DaismConfig
    dtype: str
    dims: Tuple[int, int, int]
    macs: int
    repeat: int


_OBSERVERS: List[Callable[[SiteEvent], None]] = []


@contextlib.contextmanager
def observe_sites(callback: Callable[[SiteEvent], None]):
    """Deliver a :class:`SiteEvent` to ``callback`` for every site resolved
    (with ``record=True``) inside the with-block.

    This is how ``repro.analyze`` materializes the op-site graph from a
    ``jax.eval_shape`` trace without touching the per-policy resolution log.
    """
    _OBSERVERS.append(callback)
    try:
        yield
    finally:
        _OBSERVERS.remove(callback)


def _energy_per_mult_pj(cfg: DaismConfig, dtype_name: str) -> float:
    """Estimated pJ per multiplication (core/energy model, Eq 4-6)."""
    from repro.core import energy as E

    if dtype_name not in ("bfloat16", "float32"):
        dtype_name = "float32"
    exp = E.exponent_handling_energy(dtype_name)
    if cfg.exact:
        return E.total(E.eyeriss_energy_per_mult(
            dtype_name, truncated=False)) + exp
    return E.total(E.daism_energy_per_mult(cfg.variant, dtype_name)) + exp


def energy_per_mult_pj(cfg: DaismConfig, dtype_name: str) -> float:
    """Public per-mult energy estimate (the analyzer's site table uses it)."""
    return _energy_per_mult_pj(cfg, dtype_name)


def site_report(policy: ApproxPolicy) -> str:
    """Human-readable per-site resolution table with energy estimates.

    Covers the sites traced so far under ``policy``; ``macs`` is the
    multiply count of the most recent trace of each site (batch-shaped),
    and the energy column is macs x the analytical per-mult model.
    """
    log = _LOG.get(policy, {})
    if not log:
        return (f"policy {policy.name or '<anonymous>'}: "
                "no sites resolved yet (trace a model first)")
    rows, total_pj, exact_pj = [], 0.0, 0.0
    for (path, kind), (cfg, dtype_name, macs) in sorted(log.items()):
        pj = macs * _energy_per_mult_pj(cfg, dtype_name)
        base = macs * _energy_per_mult_pj(
            DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT),
            dtype_name)
        total_pj += pj
        exact_pj += base
        rows.append((path, kind.value, describe_config(cfg), macs, pj))
    width = max(len(r[0]) for r in rows)
    lines = [f"== per-site resolution ({policy.name or '<anonymous>'}) =="]
    for path, kind, conf, macs, pj in rows:
        lines.append(f"  {path:<{width}}  {kind:<10s} {conf:<18s} "
                     f"{macs:>12,d} mults  {pj / 1e6:>10.2f} uJ")
    if exact_pj > 0:
        lines.append(
            f"  estimated multiply energy {total_pj / 1e6:.2f} uJ "
            f"(saves {100 * (1 - total_pj / exact_pj):.1f}% vs all-exact "
            f"{exact_pj / 1e6:.2f} uJ)")
    return "\n".join(lines)


def estimated_energy_uj(policy: ApproxPolicy) -> Tuple[float, float]:
    """(policy_energy, all_exact_energy) in uJ over the traced sites."""
    log = _LOG.get(policy, {})
    total = base = 0.0
    exact_cfg = DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT)
    for (_, _), (cfg, dtype_name, macs) in log.items():
        total += macs * _energy_per_mult_pj(cfg, dtype_name)
        base += macs * _energy_per_mult_pj(exact_cfg, dtype_name)
    return total / 1e6, base / 1e6


# ---------------------------------------------------------------------------
# Kernel cache
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def matmul_kernel(cfg: DaismConfig) -> Callable:
    """One jitted 2-D approximate matmul per distinct resolved config.

    The lru_cache plus jit's own (shape-keyed) cache mean a mixed policy
    compiles each unique (config, shape) combination once, however many
    sites share it. ``kernel_stats()`` exposes build/trace counters for the
    cache-hit tests.
    """
    from repro.core.gemm import daism_matmul

    _STATS["kernel_builds"] += 1

    def kernel(a, w):
        _STATS["kernel_traces"] += 1  # runs at trace time only
        return daism_matmul(a, w, cfg)

    return jax.jit(kernel)


@functools.lru_cache(maxsize=None)
def attention_kernel(cfg: DaismConfig) -> Callable:
    """One jitted flash-attention callable per distinct resolved config.

    ``kernel(q, k, v, causal)`` takes (B, S, H, D) tensors (GQA head repeat
    and ragged-length padding happen inside the wrapper); ``causal`` is a
    static argument. Exact configs run the kernel with MXU contractions
    (``variant=None``); approximate configs fuse the config's shift-plane
    product into the QK/PV contractions.
    """
    from repro.kernels.flash_attention import flash_attention_bhsd

    _STATS["kernel_builds"] += 1
    variant = None if cfg.exact else cfg.variant
    interpret = auto_interpret(cfg)

    def kernel(q, k, v, causal):
        _STATS["kernel_traces"] += 1  # runs at trace time only
        return flash_attention_bhsd(q, k, v, causal=causal, variant=variant,
                                    interpret=interpret)

    return jax.jit(kernel, static_argnames=("causal",))


def kernel_stats() -> Dict[str, int]:
    info = matmul_kernel.cache_info()
    return dict(_STATS, cache_hits=info.hits, cache_misses=info.misses,
                cached_kernels=info.currsize)


# ---------------------------------------------------------------------------
# Injection points
# ---------------------------------------------------------------------------


def resolve_site(policy: ApproxPolicy, name: str, kind: OpKind, dtype,
                 *, record: bool = True, macs: int = 0,
                 dims: Tuple[int, int, int] = (0, 0, 0),
                 attn_eligible: bool = True) -> DaismConfig:
    """Resolve + validate the config for the site named ``name`` under the
    ambient site scope. Returns the (frozen) resolved DaismConfig.

    ATTN_QK sites resolve to their *effective* config (see
    :func:`effective_attn_config`): the rule's numerics apply only when it
    opts into the flash kernel and the caller's shape is eligible
    (``attn_eligible``); otherwise the site runs — and is recorded as —
    EXACT.
    """
    path = current_path(name)
    kind = OpKind(kind)
    cfg = policy.resolve(path, kind)
    if kind is OpKind.ATTN_QK:
        cfg = effective_attn_config(cfg, eligible=attn_eligible)
        if not cfg.exact and jnp.dtype(dtype).name != "bfloat16":
            raise ValueError(
                f"site {path!r}: flash attention with a DAISM variant is "
                f"bfloat16-only (got {jnp.dtype(dtype).name}); run the site "
                "exact (drop the variant, keep ':flash') or switch the "
                "compute dtype to bfloat16")
    validate_for_dtype(cfg, dtype, site=path)
    if record:
        repeat = current_repeat()
        _record(policy, path, kind, cfg, dtype, macs * repeat)
        for cb in _OBSERVERS:
            cb(SiteEvent(path=path, kind=kind, config=cfg,
                         dtype=jnp.dtype(dtype).name, dims=dims,
                         macs=macs * repeat, repeat=repeat))
    return cfg


def policy_dot(policy: ApproxPolicy, x, w, *, name: str,
               kind: OpKind = OpKind.DENSE, record: bool = True):
    """``x @ w`` over the last axis of ``x`` with site-resolved numerics.

    Exact sites preserve the plain ``jnp.dot`` deployment path (weights cast
    to the activation dtype); approximate sites run the DAISM GEMM through
    the per-config kernel cache. Output dtype always matches ``x``.
    """
    k = x.shape[-1]
    n = w.shape[-1]
    m = int(np.prod(x.shape[:-1], dtype=np.int64))
    macs = m * int(k) * int(n)
    cfg = resolve_site(policy, name, kind, x.dtype, record=record, macs=macs,
                       dims=(m, int(k), int(n)))
    if cfg.exact:
        return jnp.dot(x, w.astype(x.dtype))
    out = matmul_kernel(cfg)(x.reshape(-1, k), w)
    return out.reshape(*x.shape[:-1], n).astype(x.dtype)


def make_dot(policy: ApproxPolicy) -> Callable:
    """Bind ``policy`` into a ``dot(x, w, *, name, kind, record)`` callable —
    the AQT-style injectable matmul models consume."""
    return functools.partial(policy_dot, policy)


def policy_conv2d(policy: ApproxPolicy, x, kernel, *, name: str,
                  stride: int = 1, padding: str = "SAME",
                  record: bool = True):
    """NHWC conv with site-resolved numerics (im2col + DAISM GEMM when the
    site resolves approximate, ``lax.conv_general_dilated`` when exact)."""
    from repro.core.gemm import conv2d_im2col

    kh, kw, cin, cout = kernel.shape
    nb, h, wdim = x.shape[0], x.shape[1], x.shape[2]
    if padding == "SAME":
        ho, wo = -(-h // stride), -(-wdim // stride)
    else:  # VALID
        ho, wo = -(-(h - kh + 1) // stride), -(-(wdim - kw + 1) // stride)
    macs = nb * ho * wo * kh * kw * cin * cout
    cfg = resolve_site(policy, name, OpKind.CONV, x.dtype, record=record,
                       macs=macs, dims=(nb * ho * wo, kh * kw * cin, cout))
    return conv2d_im2col(x, kernel.astype(x.dtype), cfg, stride=stride,
                         padding=padding).astype(x.dtype)


def policy_expert_matmul(policy: ApproxPolicy, x, w, *, name: str,
                         record: bool = True):
    """(E, C, d) x (E, d, f) -> (E, C, f) batched expert GEMM."""
    e, c, d = x.shape
    f = w.shape[-1]
    macs = e * c * d * f
    cfg = resolve_site(policy, name, OpKind.MOE_EXPERT, x.dtype,
                       record=record, macs=macs, dims=(c, d, f))
    if cfg.exact:
        return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))
    kern = matmul_kernel(cfg)
    return jax.vmap(lambda xe, we: kern(xe, we))(x, w).astype(x.dtype)
