"""Approximation policies: ordered site-pattern rules -> DaismConfig.

An :class:`ApproxPolicy` is a frozen, hashable value (usable as a ``jax.jit``
static argument) holding an ordered tuple of :class:`Rule`. Resolution is
first-match-wins over the rules, falling back to ``default``.

Rule patterns are ``fnmatch`` globs over the site path (``*`` crosses ``/``
separators, so ``*/attn/*`` matches ``decoder/layer_3/attn/wq``). A pattern
starting with ``@`` matches the site's :class:`~repro.policy.sites.OpKind`
value instead (``@lm_head``, ``@conv``, ``@moe_expert``).

Spec mini-language (CLI ``--policy`` flags, :func:`parse_policy`)::

    */attn/*=exact,*/layer_0/*=exact,@lm_head=exact,*=pc3_tr

Each comma-separated rule is ``pattern=variant[:backend][:flash]`` (the
``flash`` token opts attention-score sites into the fused Pallas kernel); a
trailing ``*=...`` rule (or the ``default=`` key) sets the fallback config.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
from typing import Callable, Iterable, Optional, Sequence, Tuple

from repro.core.config import Backend, DaismConfig, Variant

from .sites import OpKind

EXACT = DaismConfig(variant=Variant.EXACT, backend=Backend.EXACT)


@dataclasses.dataclass(frozen=True)
class Rule:
    """One policy rule: glob ``pattern`` over site paths -> ``config``.

    ``pattern`` beginning with ``@`` matches the OpKind value instead of the
    path (e.g. ``@lm_head``). ``kind`` additionally restricts a path pattern
    to one OpKind when set.
    """

    pattern: str
    config: DaismConfig
    kind: Optional[OpKind] = None

    def matches(self, path: str, kind: OpKind) -> bool:
        if self.kind is not None and kind is not self.kind:
            return False
        if self.pattern.startswith("@"):
            return self.pattern[1:] == kind.value
        return fnmatch.fnmatchcase(path, self.pattern)


@dataclasses.dataclass(frozen=True)
class ApproxPolicy:
    """Ordered first-match-wins mapping of op-sites to DAISM numerics.

    Frozen + hashable: passes through ``jax.jit`` static arguments and keys
    the dispatcher's kernel/resolution caches. Build one with the
    constructors below, :func:`parse_policy`, or directly from rules.
    """

    rules: Tuple[Rule, ...] = ()
    default: DaismConfig = EXACT
    name: str = ""

    def resolve(self, path: str, kind: OpKind = OpKind.DENSE) -> DaismConfig:
        """First matching rule's config, else ``default``."""
        return _resolve_cached(self, path, OpKind(kind))

    # -- constructors ------------------------------------------------------

    @classmethod
    def uniform(cls, config: DaismConfig, name: str = "") -> "ApproxPolicy":
        """Every site uses ``config`` (the legacy ``ArchConfig.daism`` shape)."""
        return cls(rules=(), default=config,
                   name=name or f"uniform:{config.variant.value}")

    @classmethod
    def first_last_exact(cls, base: DaismConfig, n_layers: int,
                         name: str = "") -> "ApproxPolicy":
        """First layer, last layer, and the lm_head run exact; the rest
        (the error-tolerant middle of the network) uses ``base``."""
        rules = (
            Rule("*/layer_0/*", EXACT),
            Rule(f"*/layer_{n_layers - 1}/*", EXACT),
            Rule("@lm_head", EXACT),
        )
        return cls(rules=rules, default=base,
                   name=name or f"first_last_exact:{base.variant.value}")

    @classmethod
    def attention_exact(cls, base: DaismConfig,
                        name: str = "") -> "ApproxPolicy":
        """Attention projections stay exact; everything else uses ``base``."""
        rules = (Rule("*/attn/*", EXACT), Rule("*/xattn/*", EXACT))
        return cls(rules=rules, default=base,
                   name=name or f"attention_exact:{base.variant.value}")

    @classmethod
    def depth_schedule(cls, configs: Sequence[DaismConfig],
                       default: DaismConfig = EXACT,
                       name: str = "") -> "ApproxPolicy":
        """``configs[i]`` applies to every site under ``*/layer_{i}/*``.

        Sites outside any layer scope (e.g. the lm_head) use ``default``.
        """
        rules = tuple(Rule(f"*/layer_{i}/*", c)
                      for i, c in enumerate(configs))
        return cls(rules=rules, default=default, name=name or "depth_schedule")

    # -- introspection -----------------------------------------------------

    def describe(self) -> str:
        lines = [f"policy {self.name or '<anonymous>'}:"]
        for r in self.rules:
            kind = f" [{r.kind.value}]" if r.kind else ""
            lines.append(f"  {r.pattern}{kind} -> {describe_config(r.config)}")
        lines.append(f"  * -> {describe_config(self.default)} (default)")
        return "\n".join(lines)


@functools.lru_cache(maxsize=4096)
def _resolve_cached(policy: ApproxPolicy, path: str,
                    kind: OpKind) -> DaismConfig:
    for rule in policy.rules:
        if rule.matches(path, kind):
            return rule.config
    return policy.default


def describe_config(cfg: DaismConfig) -> str:
    flash = cfg.attn_kernel == "flash"
    if cfg.exact:
        return "exact:flash" if flash else "exact"
    tags = [cfg.variant.value, cfg.backend.value]
    if flash:
        tags.append("flash")
    if cfg.calibrated:
        tags.append("calibrated")
    if cfg.backward == "approx":
        tags.append("bwd=approx")
    return ":".join(tags)


# ---------------------------------------------------------------------------
# Spec parsing (CLI mini-language)
# ---------------------------------------------------------------------------

_VARIANT_NAMES = {v.value for v in Variant}
_BACKEND_NAMES = {b.value for b in Backend}


def parse_config(spec: str) -> DaismConfig:
    """``variant[:backend][:flash]`` -> DaismConfig.

    ``exact`` -> the exact config; a trailing ``flash`` token sets
    ``attn_kernel='flash'`` so attention-score sites matched by the rule
    dispatch to the fused Pallas flash-attention kernel (``exact:flash``
    runs it with MXU contractions; ``pc3_tr:flash`` fuses the approximate
    products). Without it, attention-score sites stay on the exact jnp
    online-softmax path whatever the rule's numerics say.
    """
    parts = spec.strip().split(":")
    attn_kernel = "jnp"
    if len(parts) > 1 and parts[-1] == "flash":
        attn_kernel = "flash"
        parts = parts[:-1]
    variant = parts[0]
    if variant not in _VARIANT_NAMES:
        raise ValueError(
            f"unknown variant {variant!r}; expected one of "
            f"{sorted(_VARIANT_NAMES)}")
    if variant == Variant.EXACT.value:
        if len(parts) > 1:
            raise ValueError(f"config spec {spec!r}: 'exact' takes no "
                             "backend (only an optional ':flash')")
        return EXACT if attn_kernel == "jnp" else EXACT.replace(
            attn_kernel="flash")
    backend = parts[1] if len(parts) > 1 else Backend.JNP.value
    if backend not in _BACKEND_NAMES:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of "
            f"{sorted(_BACKEND_NAMES)}")
    if len(parts) > 2:
        raise ValueError(f"config spec {spec!r} has too many ':' fields "
                         "(expected variant[:backend][:flash])")
    return DaismConfig(variant=Variant(variant), backend=Backend(backend),
                       attn_kernel=attn_kernel)


def parse_policy(spec: str, default: DaismConfig = EXACT,
                 name: str = "") -> ApproxPolicy:
    """Parse ``pattern=variant[:backend],...`` into an ApproxPolicy.

    Entries become rules in the order given (first match wins), so a ``*=``
    catch-all shadows everything after it; a ``default=...`` entry sets the
    fallback for sites no rule matches (``exact`` unless overridden).

    Two rules with the same glob are rejected outright (the second can never
    fire); non-identical overlaps are the linter's shadowing check
    (``repro.analyze``), not a parse error.
    """
    rules = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "=" not in item:
            raise ValueError(
                f"bad policy rule {item!r}: expected pattern=variant[:backend]")
        pattern, _, conf = item.partition("=")
        pattern = pattern.strip()
        cfg = parse_config(conf)
        if pattern == "default":
            default = cfg
        else:
            for j, prev in enumerate(rules):
                if prev.pattern == pattern and prev.kind is None:
                    raise ValueError(
                        f"duplicate policy rule for pattern {pattern!r}: "
                        f"rules {j} ({prev.pattern}="
                        f"{describe_config(prev.config)}) and {len(rules)} "
                        f"({pattern}={describe_config(cfg)}) target the same "
                        "glob — first match wins, the second can never fire")
            rules.append(Rule(pattern, cfg))
    return ApproxPolicy(rules=tuple(rules), default=default,
                        name=name or spec)


# ---------------------------------------------------------------------------
# Scan segmentation
# ---------------------------------------------------------------------------

SitesFn = Callable[[int], Iterable[Tuple[str, OpKind]]]


def layer_signature(policy: ApproxPolicy, sites: Iterable[Tuple[str, OpKind]]
                    ) -> Tuple[DaismConfig, ...]:
    """Resolved configs for a layer's probe sites (its policy fingerprint).

    ATTN_QK probes use the *effective* attention config (what the traced
    layer actually runs — see ``dispatch.effective_attn_config``), so a
    catch-all numerics rule that leaves attention on the exact jnp path
    doesn't split scan segments over a difference that never reaches HLO.
    """
    from .dispatch import effective_attn_config

    out = []
    for path, kind in sites:
        cfg = policy.resolve(path, kind)
        if OpKind(kind) is OpKind.ATTN_QK:
            cfg = effective_attn_config(cfg)
        out.append(cfg)
    return tuple(out)


def plan_segments(policy: ApproxPolicy, sites_fn: SitesFn, lo: int, hi: int
                  ) -> Tuple[Tuple[int, int], ...]:
    """Partition layers ``[lo, hi)`` into maximal runs with identical
    resolved configs, so each run can share one ``lax.scan`` trace.

    ``sites_fn(i)`` yields the (path, kind) probe sites of layer ``i`` —
    every contraction site the layer contains, with the exact paths the
    traced model will use. A uniform policy yields a single segment
    (identical HLO to the un-segmented scan).
    """
    if hi <= lo:
        return ()
    segments = []
    start = lo
    sig = layer_signature(policy, sites_fn(lo))
    for i in range(lo + 1, hi):
        s = layer_signature(policy, sites_fn(i))
        if s != sig:
            segments.append((start, i))
            start, sig = i, s
    segments.append((start, hi))
    return tuple(segments)
