"""Exact FLOP counting from the jaxpr (scan-trip-count aware).

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless
of trip count (verified in tests/test_roofline.py), which under-reports a
scanned 96-layer transformer by ~96x. The jaxpr, by contrast, carries every
``scan`` with its static ``length`` — so we walk it recursively, multiplying
body costs by trip counts. Dots/convs use exact 2mnk accounting; elementwise
ops cost 1/output element; data movement costs 0 FLOPs.

This measures the *compiled-intent* FLOPs (including remat recompute, AD
backward, DAISM bit-ops) — the honest numerator for the roofline compute
term and the denominator for MODEL_FLOPS/HLO_FLOPs usefulness.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore

# primitives with zero flops (pure data movement / bookkeeping)
_ZERO = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "concatenate",
    "slice", "dynamic_slice", "dynamic_update_slice", "gather", "scatter",
    "pad", "rev", "bitcast_convert_type", "convert_element_type", "copy",
    "iota", "stop_gradient", "device_put", "split", "and", "or", "xor",
    "shift_left", "shift_right_logical", "shift_right_arithmetic", "not",
    "eq", "ne", "lt", "le", "gt", "ge", "select_n", "clamp", "sign",
    "is_finite", "population_count", "real", "imag", "sharding_constraint",
    "squeeze", "expand_dims", "argmax", "argmin",
}

_EXPENSIVE = {"exp": 1, "log": 1, "tanh": 1, "logistic": 1, "erf": 1,
              "rsqrt": 1, "sqrt": 1, "sin": 1, "cos": 1, "pow": 1,
              "integer_pow": 1, "div": 1, "rem": 1, "cbrt": 1, "exp2": 1}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs = eqn.invars[0].aval.shape
    batch = 1
    for d in lb:
        batch *= lhs[d]
    k = 1
    for d in lc:
        k *= lhs[d]
    m = 1
    for i, d in enumerate(lhs):
        if i not in lc and i not in lb:
            m *= d
    rhs = eqn.invars[1].aval.shape
    n = 1
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            n *= d
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = _size(eqn.outvars[0].aval)
    rhs = eqn.invars[1].aval.shape  # kernel
    dn = eqn.params.get("dimension_numbers")
    fgc = eqn.params.get("feature_group_count", 1)
    k_elems = int(np.prod(rhs))
    cin_per_out = k_elems / max(rhs[dn.rhs_spec[0]], 1) / fgc \
        if dn is not None else k_elems
    return 2.0 * out * cin_per_out


def jaxpr_flops(jaxpr, consts_mult: float = 1.0) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif prim == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += eqn.params["length"] * jaxpr_flops(body)
        elif prim == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            total += jaxpr_flops(body)  # unknown trip: conservative 1
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max(jaxpr_flops(b.jaxpr) for b in branches)
        elif prim in ("pjit", "closed_call", "core_call", "remat_call",
                      "xla_call", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat",
                      "remat2", "shard_map", "jit"):
            inner = (eqn.params.get("jaxpr")
                     or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                inner_j = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                total += jaxpr_flops(inner_j)
        elif prim == "custom_vjp_call_jaxpr":
            total += jaxpr_flops(eqn.params["fun_jaxpr"].jaxpr)
        elif prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                      "reduce_and", "reduce_or", "argmax", "argmin",
                      "cumsum", "cumlogsumexp", "cummax", "cumprod",
                      "reduce_window_sum", "reduce_window_max"):
            total += _size(eqn.invars[0].aval)
        elif prim in ("sort", "top_k"):
            n = _size(eqn.invars[0].aval)
            total += n * max(math.log2(max(n, 2)), 1)
        elif prim in _ZERO:
            pass
        elif prim in _EXPENSIVE:
            total += _EXPENSIVE[prim] * _size(eqn.outvars[0].aval)
        else:
            # default: one flop per output element (add/mul/sub/max/...)
            total += sum(_size(v.aval) for v in eqn.outvars)
    return total * consts_mult


def count_flops(fn, *args, **kw) -> float:
    """Global FLOPs of ``fn(*args)`` (trace-only; no execution)."""
    jaxpr = jax.make_jaxpr(fn, **kw)(*args)
    return jaxpr_flops(jaxpr.jaxpr)
