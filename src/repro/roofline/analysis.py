"""Three-term roofline from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

``compiled.cost_analysis()`` reports the per-device SPMD program's flops and
bytes. Collective bytes are NOT in cost_analysis: we parse the optimized HLO
text (``compiled.as_text()``), sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
and apply ring-algorithm wire factors with the group size N parsed from
``replica_groups``:

    all-reduce      2 (N-1)/N x bytes        (ring reduce+broadcast phases)
    all-gather      (N-1)/N x result bytes
    reduce-scatter  (N-1)/N x operand bytes (~= result x (N-1))
    all-to-all      (N-1)/N x bytes
    collective-permute  1 x bytes

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE[shape]{layout} op-name(` — possibly a tuple of types.
_LINE_RE = re.compile(
    r"=\s*(?P<types>\(?[a-z0-9_]+\[[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(types: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(types):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format: replica_groups=[num_groups,group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].replace("{", " ").strip()
        if first:
            return len(first.split(","))
    return default


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    r = (n - 1) / n
    return {"all-reduce": 2 * r, "all-gather": r, "reduce-scatter": r,
            "all-to-all": r, "collective-permute": 1.0}[op]


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op: str, b: float):
        self.wire_bytes += b
        self.by_op[op] = self.by_op.get(op, 0.0) + b
        self.count += 1


def collective_bytes_from_hlo(hlo_text: str, default_group: int
                              ) -> CollectiveStats:
    stats = CollectiveStats()
    seen_start = set()
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        op = m.group("op")
        b = _shape_bytes(m.group("types"))
        n = _group_size(line, default_group)
        stats.add(op, b * _wire_factor(op, n))
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs x chips)
    peak_fraction: float         # compute_s / max(all terms)
    memory_per_device_gb: float
    collective_by_op: Dict[str, float]

    def terms(self) -> Dict[str, float]:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}


def xla_cost(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` normalized across jax versions.

    Newer jax returns a flat dict; the pinned 0.4.x returns a one-element
    list of dicts (one per computation). Always returns the dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            n_chips: int, model_flops: float,
            memory_per_device: Optional[float] = None) -> Roofline:
    cost = xla_cost(compiled)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes_from_hlo(compiled.as_text(), n_chips)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = stats.wire_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    total = max(max(terms.values()), 1e-30)
    if memory_per_device is None:
        try:
            ma = compiled.memory_analysis()
            memory_per_device = (ma.argument_size_in_bytes
                                 + ma.output_size_in_bytes
                                 + ma.temp_size_in_bytes)
        except Exception:
            memory_per_device = 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=byts,
        wire_bytes_per_device=stats.wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / (flops * n_chips)) if flops else 0.0,
        peak_fraction=compute_s / total,
        memory_per_device_gb=memory_per_device / 2**30,
        collective_by_op=stats.by_op,
    )


def model_flops_estimate(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (forward) with N = active params.

    MoE: N counts topk/n_experts of expert params (active). Decode: D = one
    token per step x batch.
    """
    import numpy as np
    from repro.models.registry import build_model
    import jax

    model = build_model(cfg)
    shapes, _ = model.init(jax.random.PRNGKey(0), abstract=True)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_total = 0
    n_expert = 0
    for path, leaf in flat:
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        n = int(np.prod(leaf.shape))
        if any(k in keys for k in ("w_in", "w_gate", "w_out")) and cfg.n_experts:
            n_expert += n
        else:
            n_total += n
    active = n_total + (n_expert * cfg.topk // max(cfg.n_experts, 1))
    tokens = batch * (1 if shape_kind == "decode" else seq)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * active * tokens
