"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from results/.

    PYTHONPATH=src python -m repro.roofline.report [--tag main]
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results")


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def load(tag):
    with open(os.path.join(RESULTS, f"dryrun_{tag}.json")) as f:
        return json.load(f)


def dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | compile s | mem/dev GB | args GB | temps GB | dominant collective |",
             "|---|---|---|---:|---:|---:|---:|---|"]
    for v in sorted(recs.values(), key=lambda v: (v["arch"], v["shape"],
                                                  v["mesh"])):
        if v["status"] == "skip":
            lines.append(f"| {v['arch']} | {v['shape']} | {v['mesh']} | "
                         f"— | — | — | — | *mandated skip* |")
            continue
        if v["status"] != "ok":
            lines.append(f"| {v['arch']} | {v['shape']} | {v['mesh']} | "
                         f"ERROR | | | | {v.get('error', '')[:60]} |")
            continue
        dom = max(v["collective_by_op"].items(), key=lambda kv: kv[1],
                  default=("none", 0))
        lines.append(
            f"| {v['arch']} | {v['shape']} | {v['mesh']} | "
            f"{v['compile_s']:.0f} | {v['mem_per_device_gb']:.2f} | "
            f"{_fmt_bytes(v['arg_bytes'])} | {_fmt_bytes(v['temp_bytes'])} | "
            f"{dom[0]} ({dom[1] / 2**30:.1f} GB) |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | MODEL_FLOPS | useful | peak frac |",
             "|---|---|---:|---:|---:|---|---:|---:|---:|"]
    for v in sorted(recs.values(), key=lambda v: (v["arch"], v["shape"])):
        if v["status"] != "ok" or v["mesh"] != "single":
            continue
        tmax = max(v["compute_s"], v["memory_s"], v["collective_s"], 1e-30)
        lines.append(
            f"| {v['arch']} | {v['shape']} | {v['compute_s']:.4f} | "
            f"{v['memory_s']:.4f} | {v['collective_s']:.4f} | "
            f"{v['bottleneck']} | {v['model_flops']:.3e} | "
            f"{v['useful_ratio']:.2f} | {v['compute_s'] / tmax:.3f} |")
    return "\n".join(lines)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--tag", default="main")
    p.add_argument("--which", default="both",
                   choices=["dryrun", "roofline", "both"])
    args = p.parse_args()
    recs = load(args.tag)
    if args.which in ("dryrun", "both"):
        print("### Dry-run table\n")
        print(dryrun_table(recs))
    if args.which in ("roofline", "both"):
        print("\n### Roofline table (single-pod)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
