"""Approximate GEMM: exact accumulation of DAISM-approximate scalar products.

This is the paper's contribution lifted to the operation DNNs actually need:
``out[m, n] = sum_k approx(a[m, k] * w[k, n])`` where the per-element product
uses one of the Table-1 multiplier variants and the reduction is exact
(DAISM's accumulator is an exact adder, paper §4.1).

Backends
  * ``jnp``    — vectorized bit ops, K-chunked to bound the (M, Kc, N)
                 intermediate. The reference semantics; differentiable via
                 ``custom_vjp``.
  * ``lut``    — bf16 gather fast path (bit-identical, see core/lut.py).
  * ``pallas`` — VMEM-tiled TPU kernel (kernels/daism_matmul.py).
  * ``exact``  — plain MXU matmul (deployment path).

Autodiff: the forward pass uses the approximate product. The backward pass is
straight-through (exact matmul gradients) by default, or routed through the
approximate GEMM as well with ``backward='approx'`` (paper §5.1.2 notes models
can be *trained* under the approximation).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .bitops import round_up as _round_up
from .config import Backend, DaismConfig, Variant
from .floatmul import approx_mul_to_f32
from .lut import approx_mul_to_f32_lut


def _product_fn(cfg: DaismConfig) -> Callable:
    if cfg.backend is Backend.LUT:
        return functools.partial(approx_mul_to_f32_lut, variant=cfg.variant)
    return functools.partial(approx_mul_to_f32, variant=cfg.variant)


def _matmul_chunked(a: jnp.ndarray, w: jnp.ndarray, cfg: DaismConfig) -> jnp.ndarray:
    """(M, K) x (K, N) -> (M, N) f32, chunking K to bound peak memory."""
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    prod = _product_fn(cfg)
    kc = min(cfg.k_chunk, k)
    k_pad = _round_up(k, kc)
    if k_pad != k:  # zero-padding is exact: approx(0 * w) == 0
        a = jnp.pad(a, ((0, 0), (0, k_pad - k)))
        w = jnp.pad(w, ((0, k_pad - k), (0, 0)))
    steps = k_pad // kc
    a3 = a.reshape(m, steps, kc).transpose(1, 0, 2)     # (steps, M, Kc)
    w3 = w.reshape(steps, kc, n)                         # (steps, Kc, N)

    def body(acc, operands):
        ac, wc = operands
        p = prod(ac[:, :, None], wc[None, :, :])         # (M, Kc, N) f32
        return acc + p.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    out, _ = lax.scan(body, acc0, (a3, w3))
    return out


def _matmul_fwd_impl(a: jnp.ndarray, w: jnp.ndarray, cfg: DaismConfig) -> jnp.ndarray:
    if cfg.exact:
        return jnp.matmul(a.astype(jnp.float32), w.astype(jnp.float32))
    if cfg.backend is Backend.PALLAS:
        from repro.kernels import ops as kops  # local import: avoid cycle

        return kops.daism_matmul_pallas(a, w, cfg)
    return _matmul_chunked(a, w, cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _daism_matmul(a: jnp.ndarray, w: jnp.ndarray, cfg: DaismConfig) -> jnp.ndarray:
    return _matmul_fwd_impl(a, w, cfg)


def _fwd(a, w, cfg):
    return _matmul_fwd_impl(a, w, cfg), (a, w)


def _bwd(cfg, res, g):
    a, w = res
    g = g.astype(jnp.float32)
    if cfg.backward == "approx" and not cfg.exact:
        bcfg = cfg  # same approximate numerics for the gradient GEMMs
        da = _matmul_fwd_impl(g.astype(a.dtype), w.T.astype(a.dtype), bcfg)
        dw = _matmul_fwd_impl(a.T.astype(a.dtype), g.astype(a.dtype), bcfg)
    else:  # straight-through: exact gradients
        da = jnp.matmul(g, w.astype(jnp.float32).T)
        dw = jnp.matmul(a.astype(jnp.float32).T, g)
    return da.astype(a.dtype), dw.astype(w.dtype)


_daism_matmul.defvjp(_fwd, _bwd)


def daism_matmul(a: jnp.ndarray, w: jnp.ndarray, cfg: DaismConfig) -> jnp.ndarray:
    """2-D approximate matmul, (M, K) @ (K, N) -> (M, N) in ``cfg.accum_dtype``."""
    out = _daism_matmul(a, w, cfg)
    if cfg.calibrated and not cfg.exact:
        from .lut import shrinkage_factor  # bf16-table statistic

        out = out * (1.0 / shrinkage_factor(cfg.variant))
    return out.astype(cfg.accum_dtype)


def daism_dot(x: jnp.ndarray, w: jnp.ndarray, cfg: DaismConfig) -> jnp.ndarray:
    """``x @ w`` over the last axis of ``x``: (..., K) @ (K, N) -> (..., N).

    The deployment path (cfg.exact) preserves input dtype semantics of
    ``jnp.dot``; approximate paths accumulate in f32 then cast to
    ``cfg.accum_dtype``.
    """
    if cfg.exact:
        return jnp.dot(x, w)
    lead = x.shape[:-1]
    k = x.shape[-1]
    out = daism_matmul(x.reshape(-1, k), w, cfg)
    return out.reshape(*lead, w.shape[-1])


def conv2d_im2col(
    x: jnp.ndarray,
    kernel: jnp.ndarray,
    cfg: DaismConfig,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jnp.ndarray:
    """NHWC conv via im2col + DAISM GEMM — how the accelerator executes convs
    (kernels flattened into SRAM rows, paper Fig 4). kernel: (kh, kw, cin, cout).
    """
    kh, kw, cin, cout = kernel.shape
    if cfg.exact:
        return lax.conv_general_dilated(
            x, kernel, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
    patches = lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, Ho, Wo, kh*kw*cin) with feature dim ordered (cin, kh, kw)
    nb, ho, wo, feat = patches.shape
    # conv_general_dilated_patches orders features as (cin, kh, kw); reorder
    # the kernel to match.
    kmat = kernel.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    out = daism_matmul(patches.reshape(-1, feat), kmat, cfg)
    return out.reshape(nb, ho, wo, cout)
