"""DAISM core: the paper's contribution as composable JAX modules."""
from .config import ALL_VARIANTS, Backend, DaismConfig, Variant, mantissa_bits
from .floatmul import approx_mul, approx_mul_to_f32
from .gemm import conv2d_im2col, daism_dot, daism_matmul
from .multiplier import (
    approx_mul_int_signmag,
    approx_mul_uint,
    approx_mul_uint_planes,
    error_distance,
)

__all__ = [
    "ALL_VARIANTS",
    "Backend",
    "DaismConfig",
    "Variant",
    "mantissa_bits",
    "approx_mul",
    "approx_mul_to_f32",
    "conv2d_im2col",
    "daism_dot",
    "daism_matmul",
    "approx_mul_int_signmag",
    "approx_mul_uint",
    "approx_mul_uint_planes",
    "error_distance",
]
