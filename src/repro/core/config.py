"""DAISM configuration objects.

A :class:`DaismConfig` fully determines the numerics of the approximate
multiplier (paper Table 1) plus the execution backend used to realize it.
It is a frozen, hashable dataclass so it can be passed as a static argument
through ``jax.jit`` boundaries.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Variant(str, enum.Enum):
    """Multiplier variants from paper Table 1 (+ exact baseline)."""

    EXACT = "exact"    # carry-propagating baseline multiplier
    FLA = "fla"        # full lines activation: OR of all selected partial products
    HLA = "hla"        # half lines activation: 2 reads (even/odd shifts), exact add
    PC2 = "pc2"        # pre-computed A+B head line
    PC3 = "pc3"        # pre-computed combos of A,B,C head line
    PC2_TR = "pc2_tr"  # PC2 + truncation to top-n columns
    PC3_TR = "pc3_tr"  # PC3 + truncation to top-n columns

    @property
    def truncated(self) -> bool:
        return self in (Variant.PC2_TR, Variant.PC3_TR)

    @property
    def base(self) -> "Variant":
        return {
            Variant.PC2_TR: Variant.PC2,
            Variant.PC3_TR: Variant.PC3,
        }.get(self, self)

    @property
    def memory_reads(self) -> int:
        """Paper Table 1: number of SRAM reads per multiplication."""
        return 2 if self is Variant.HLA else 1


class Backend(str, enum.Enum):
    """Execution strategy for the approximate GEMM."""

    JNP = "jnp"              # pure-jnp vectorized bit ops (reference / oracle)
    LUT = "lut"              # bf16-only: 256x256 precomputed mantissa-product table
    PALLAS = "pallas"        # Pallas TPU kernel (interpret=True on CPU)
    EXACT = "exact"          # plain MXU matmul (deployment path)


_MANTISSA_BITS = {"bfloat16": 8, "float32": 24}


@dataclasses.dataclass(frozen=True)
class DaismConfig:
    """Static numerics + backend configuration.

    Attributes:
      variant: which approximate multiplier (paper Table 1).
      backend: how to execute it.
      integer_drop_lsb: in *integer* PC2 mode, whether the LSB partial-product
        line ``H`` is sacrificed to make room for the pre-computed ``A+B``
        line (faithful to paper Fig 3). Float mode never drops lines because
        the mantissa MSB is always 1 (paper 3.4).
      accum_dtype: exact accumulator dtype used by the GEMM reduction
        (DAISM's accumulator is exact; paper 4.1).
      backward: 'ste' uses exact gradients (straight-through), 'approx'
        routes the backward GEMMs through the approximate multiplier too
        (paper 5.1.2: "The model can also be trained to use these
        approximations").
      k_chunk: K-dim chunk size used by the jnp backend to bound the
        materialized (M, Kc, N) intermediate.
      attn_kernel: how attention-score sites (OpKind.ATTN_QK) execute.
        'jnp' keeps the production online-softmax path (always exact
        numerics — neither attention operand is SRAM-stationary); 'flash'
        dispatches to the Pallas flash-attention kernel, which fuses this
        config's approximate QK/PV products with the online-softmax
        accumulator in VMEM (exact configs run the flash kernel with MXU
        contractions). Ignored by every other OpKind.
    """

    variant: Variant = Variant.PC3_TR
    backend: Backend = Backend.JNP
    integer_drop_lsb: bool = True
    accum_dtype: str = "float32"
    backward: str = "ste"  # 'ste' | 'approx'
    calibrated: bool = False  # beyond-paper: unbias the one-sided shrinkage
    k_chunk: int = 64
    # Pallas tiling knobs (block sizes for the kernel); defaults chosen so the
    # working set fits a 16 MiB VMEM budget with headroom (see kernels/).
    # bm=32 relies on the fused shift-plane sweep: the kernel's peak live
    # intermediate is (bm, K_FUSE, bn), not (bm, bk, bn).
    block_m: int = 32
    block_n: int = 128
    block_k: int = 128
    interpret: Optional[bool] = None  # None -> auto (True on CPU)
    attn_kernel: str = "jnp"  # 'jnp' | 'flash' (attention-score sites only)

    def __post_init__(self) -> None:
        if self.backward not in ("ste", "approx"):
            raise ValueError(f"backward must be 'ste'|'approx', got {self.backward}")
        if self.attn_kernel not in ("jnp", "flash"):
            raise ValueError(
                f"attn_kernel must be 'jnp'|'flash', got {self.attn_kernel!r}")
        if self.accum_dtype not in _MANTISSA_BITS:
            raise ValueError(
                f"accum_dtype must be one of {sorted(_MANTISSA_BITS)}, got "
                f"{self.accum_dtype!r}")
        if self.k_chunk < 1:
            raise ValueError(f"k_chunk must be >= 1, got {self.k_chunk}")
        if min(self.block_m, self.block_n, self.block_k) < 1:
            raise ValueError(
                "pallas block sizes must be >= 1, got "
                f"(block_m={self.block_m}, block_n={self.block_n}, "
                f"block_k={self.block_k})")
        if (self.backend is Backend.PALLAS and not self.exact
                and self.backward == "approx"):
            raise ValueError(
                "backend 'pallas' has no approximate backward kernel; use "
                "backward='ste' (exact gradients) or backend='jnp'")

    def validate_for_dtype(self, dtype, *, site: str = "") -> None:
        """Check this config can run on ``dtype`` operands (actionable error
        instead of a deep-kernel failure); see policy.dispatch."""
        from repro.policy.dispatch import validate_for_dtype

        validate_for_dtype(self, dtype, site=site)

    @property
    def exact(self) -> bool:
        return self.variant is Variant.EXACT or self.backend is Backend.EXACT

    def replace(self, **kw) -> "DaismConfig":
        return dataclasses.replace(self, **kw)


def mantissa_bits(dtype) -> int:
    """Effective mantissa width (including the implicit leading 1)."""
    import jax.numpy as jnp

    d = jnp.dtype(dtype)
    name = d.name
    if name not in _MANTISSA_BITS:
        raise ValueError(f"DAISM supports bfloat16/float32, got {name}")
    return _MANTISSA_BITS[name]


# Canonical configs used throughout benchmarks/tests (paper Table 1 order).
ALL_VARIANTS = (
    Variant.FLA,
    Variant.HLA,
    Variant.PC2,
    Variant.PC3,
    Variant.PC2_TR,
    Variant.PC3_TR,
)
