"""Analytical cycle/area model of the DAISM accelerator (paper §5.3, Fig 9).

Models the banked wired-OR SRAM architecture of Fig 4 executing a conv layer
(im2col GEMM view) and an Eyeriss-style 168-PE row-stationary baseline, the
way the paper does with Timeloop/Accelergy. Area constants are 45nm,
component-composed (SRAM macro area + PE/accumulator/decoder overheads) and
recorded explicitly; we validate the paper's *relative* Fig-9 geometry:

  * 1 x 512 kB bank: slowest (row under-utilization), largest SRAM area;
  * splitting into banks multiplies throughput (different inputs per bank);
  * 16 x 8 kB matches 4 x 128 kB cycles at the smallest area;
  * banked DAISM beats 168-PE Eyeriss in cycles at comparable area
    (headline: −43 % cycles, −25 % energy under similar constraints).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from . import energy as E
from .config import Variant


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """NHWC conv layer; defaults are VGG-8 layer 1 (paper §5.3: 224x224x3
    input, 3x3x3x64 kernel => 150,528 inputs / 1,728 kernel elements)."""

    h: int = 224
    w: int = 224
    cin: int = 3
    cout: int = 64
    kh: int = 3
    kw: int = 3
    stride: int = 1

    @property
    def out_pixels(self) -> int:
        return (self.h // self.stride) * (self.w // self.stride)

    @property
    def k_rows(self) -> int:  # im2col contraction length
        return self.kh * self.kw * self.cin

    @property
    def kernel_elements(self) -> int:
        return self.k_rows * self.cout

    @property
    def inputs(self) -> int:
        return self.h * self.w * self.cin

    @property
    def macs(self) -> int:
        return self.out_pixels * self.k_rows * self.cout


@dataclasses.dataclass(frozen=True)
class BankConfig:
    """Square SRAM banks (paper: square for manufacturability)."""

    num_banks: int = 16
    bank_kbytes: int = 32

    @property
    def bits(self) -> int:
        return self.bank_kbytes * 1024 * 8

    @property
    def side(self) -> int:  # square array: side x side bits
        return int(math.isqrt(self.bits))

    @property
    def bus_bits(self) -> int:
        return self.side

    def elements_per_row(self, dtype: str, truncated: bool) -> int:
        return E.concurrent_mults(dtype, truncated, self.bus_bits)

    @property
    def total_kbytes(self) -> int:
        return self.num_banks * self.bank_kbytes


# Paper's evaluated configurations (Fig 9) -------------------------------
FIG9_CONFIGS = (
    BankConfig(1, 512),
    BankConfig(4, 128),
    BankConfig(16, 32),
    BankConfig(16, 8),
)


# ---------------------------------------------------------------------------
# Cycles
# ---------------------------------------------------------------------------

def daism_cycles(
    layer: ConvLayer,
    banks: BankConfig,
    variant: Variant = Variant.PC3_TR,
    dtype: str = "bfloat16",
) -> Dict[str, float]:
    """Cycle count for the banked DAISM array on one conv layer.

    Each cycle a bank performs one multi-wordline read: 1 input value x
    `epr` kernel elements of one logical row. A kernel-matrix row (cout
    elements sharing the same input) spans ceil(cout/epr) logical rows; if
    cout < epr the remaining row slots hold other kernel rows which need a
    *different* input => utilization cout/epr (paper: "some input elements
    must not be multiplied by all kernel elements, which decreases
    utilization").
    """
    variant = Variant(variant)
    epr = banks.elements_per_row(dtype, variant.truncated)
    reads_per_input_row = max(1, math.ceil(layer.cout / epr))
    utilization = min(1.0, layer.cout / epr)
    reads = layer.out_pixels * layer.k_rows * reads_per_input_row
    reads *= variant.memory_reads  # HLA: 2 reads per multiplication
    cycles = reads / banks.num_banks
    # capacity: does the kernel fit? (lines per element x field bits)
    n = E.mantissa_width(dtype)
    lines = E.active_wordlines(variant, dtype) + (1 if variant.base in
                                                  (Variant.PC2, Variant.PC3) else 0)
    field = 2 * E.product_bits(dtype, variant.truncated)
    elem_bits = lines * field
    capacity_elems = banks.num_banks * banks.bits // elem_bits
    refills = max(1, math.ceil(layer.kernel_elements / capacity_elems))
    return {
        "cycles": cycles * refills,
        "utilization": utilization,
        "elements_per_row": epr,
        "pe_equivalent": banks.num_banks * epr,
        "refills": refills,
    }


def eyeriss_cycles(layer: ConvLayer, num_pes: int = 168) -> Dict[str, float]:
    """Row-stationary 168-PE baseline at ideal utilization (paper grants
    Eyeriss its best case, as we do not model its mapping losses)."""
    return {
        "cycles": layer.macs / num_pes,
        "utilization": 1.0,
        "pe_equivalent": num_pes,
    }


# ---------------------------------------------------------------------------
# Area (45nm)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AreaModel:
    """45nm component areas.

    * SRAM macro: ~0.45 um^2/bit incl. periphery at 45nm (CACTI-order);
    * bf16 truncated multiplier: ~1600 um^2 (scaled from Yin'16 fp32 synth
      with the same truncation-linear scaling as the energy model);
    * accumulator + exponent handler per concurrent output: ~500 um^2;
    * RF/scratchpad: ~1.1 um^2/bit; decoder+bus overhead: 8 % of SRAM.
    """

    sram_um2_per_bit: float = 0.45
    mult_bf16_um2: float = 1600.0
    accum_um2: float = 500.0
    rf_um2_per_bit: float = 1.1
    decoder_overhead: float = 0.08
    eyeriss_pe_ctrl_um2: float = 900.0
    eyeriss_spad_bits: int = 4384  # ~0.5 kB spads per PE (Eyeriss JSSC'17)
    eyeriss_glb_kbytes: int = 108


AREA_45NM = AreaModel()


def daism_area_mm2(
    banks: BankConfig,
    variant: Variant = Variant.PC3_TR,
    dtype: str = "bfloat16",
    area: AreaModel = AREA_45NM,
) -> float:
    epr = banks.elements_per_row(dtype, Variant(variant).truncated)
    sram = banks.num_banks * banks.bits * area.sram_um2_per_bit
    sram *= 1.0 + area.decoder_overhead  # multi-WL decoder + wider bus
    accum = banks.num_banks * epr * area.accum_um2
    rf = banks.num_banks * 1024 * 16 * area.rf_um2_per_bit  # 2 kB RF per bank
    return (sram + accum + rf) / 1e6


def eyeriss_area_mm2(num_pes: int = 168, dtype: str = "bfloat16",
                     area: AreaModel = AREA_45NM) -> float:
    pe = (area.mult_bf16_um2 + area.accum_um2 + area.eyeriss_pe_ctrl_um2
          + area.eyeriss_spad_bits * area.rf_um2_per_bit)
    glb = area.eyeriss_glb_kbytes * 1024 * 8 * area.sram_um2_per_bit
    return (num_pes * pe + glb) / 1e6


# ---------------------------------------------------------------------------
# Layer energy (ties Fig 7's per-mult numbers to Fig 9's architecture run)
# ---------------------------------------------------------------------------

def daism_layer_energy_uj(
    layer: ConvLayer,
    banks: BankConfig,
    variant: Variant = Variant.PC3_TR,
    dtype: str = "bfloat16",
) -> float:
    per = E.total(E.daism_energy_per_mult(
        variant, dtype, bank_kb=banks.bank_kbytes, bus_bits=banks.bus_bits))
    per += E.exponent_handling_energy(dtype)
    return per * layer.macs / 1e6


def eyeriss_layer_energy_uj(layer: ConvLayer, dtype: str = "bfloat16") -> float:
    per = E.total(E.eyeriss_energy_per_mult(dtype, truncated=True))
    per += E.exponent_handling_energy(dtype)
    return per * layer.macs / 1e6
