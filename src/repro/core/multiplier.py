"""The DAISM approximate multiplier family (paper §3, Table 1).

Semantics are normative per DESIGN.md §7. Everything operates on unsigned
n-bit operands held in int32 arrays; two execution forms are provided:

* **single-word** (``n <= 15``): the 2n-bit product fits an int32 lane.
  Used for bfloat16 mantissas (n=8) and the INT8 error study (Fig 5/6).
* **dual-plane** (``n <= 24``): the 2n-bit product is carried as
  ``(hi, lo)`` int32 planes (see ``bitops``). Used for float32 (n=24).

The wordline naming follows the paper: ``A`` is the partial product with
shift ``n-1`` (multiplicand aligned to the multiplier's MSB), ``B`` shift
``n-2``, ..., ``H`` shift 0 for n=8.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from . import bitops
from .bitops import Planes
from .config import Variant


def _mask(n: int) -> int:
    return (1 << n) - 1


def _bit(b: jnp.ndarray, i: int) -> jnp.ndarray:
    return (b >> i) & 1


# ---------------------------------------------------------------------------
# Single-word path (n <= 15)
# ---------------------------------------------------------------------------

def _or_lines(a: jnp.ndarray, b: jnp.ndarray, shifts) -> jnp.ndarray:
    """Wired-OR read: OR of ``a << i`` for every i in ``shifts`` with b_i=1."""
    acc = jnp.zeros_like(a)
    for i in shifts:
        acc = acc | jnp.where(_bit(b, i) == 1, a << i, 0)
    return acc


def approx_mul_uint(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n: int,
    variant: Variant,
    *,
    integer_drop_lsb: bool = True,
    msb_always_set: bool = False,
) -> jnp.ndarray:
    """Approximate product of unsigned n-bit ``a`` (multiplicand, stored in
    SRAM) and ``b`` (multiplier, drives wordline activation). n <= 15.

    ``msb_always_set`` is the float-mantissa mode (paper §3.4): the MSB of
    ``b`` is the implicit leading 1, so the ``A`` line is always active and
    no low line needs to be sacrificed for the pre-computed head lines.
    """
    if n > 15:
        raise ValueError("single-word path requires n <= 15; use the planes path")
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    variant = Variant(variant)
    base = variant.base

    if base is Variant.EXACT:
        out = a * b
    elif base is Variant.FLA:
        out = _or_lines(a, b, range(n))
    elif base is Variant.HLA:
        even = _or_lines(a, b, range(0, n, 2))
        odd = _or_lines(a, b, range(1, n, 2))
        if variant.truncated:  # mask each *read* before the exact add
            tmask = _mask(n) << n
            even, odd = even & tmask, odd & tmask
            return (even + odd) & (_mask(n) << n)
        out = even + odd
    elif base is Variant.PC2:
        b_hi = jnp.where(msb_always_set, _bit(b, n - 1) | 1, _bit(b, n - 1))
        w = 2 * b_hi + _bit(b, n - 2)          # head weight in {0..3}
        head = (a * w) << (n - 2)              # exact pre-computed line content
        lo_start = 1 if (integer_drop_lsb and not msb_always_set) else 0
        out = head | _or_lines(a, b, range(lo_start, n - 2))
    elif base is Variant.PC3:
        b_hi = jnp.where(msb_always_set, _bit(b, n - 1) | 1, _bit(b, n - 1))
        w = 4 * b_hi + 2 * _bit(b, n - 2) + _bit(b, n - 3)  # {0..7}
        head = (a * w) << (n - 3)
        lo_start = 1 if (integer_drop_lsb and not msb_always_set) else 0
        out = head | _or_lines(a, b, range(lo_start, n - 3))
    else:  # pragma: no cover
        raise ValueError(variant)

    if variant.truncated:
        out = out & (_mask(n) << n)
    return out


# ---------------------------------------------------------------------------
# Dual-plane path (n <= 24)
# ---------------------------------------------------------------------------

def _or_lines_planes(a: jnp.ndarray, b: jnp.ndarray, shifts, n: int) -> Planes:
    hi = jnp.zeros_like(a)
    lo = jnp.zeros_like(a)
    for i in shifts:
        phi, plo = bitops.planes_from_shift(a, i, n)
        sel = _bit(b, i) == 1
        hi = hi | jnp.where(sel, phi, 0)
        lo = lo | jnp.where(sel, plo, 0)
    return hi, lo


def approx_mul_uint_planes(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n: int,
    variant: Variant,
    *,
    integer_drop_lsb: bool = True,
    msb_always_set: bool = False,
) -> Planes:
    """Dual-plane form of :func:`approx_mul_uint` for n <= 24 (float32)."""
    if n > 24:
        raise ValueError("dual-plane path requires n <= 24")
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    variant = Variant(variant)
    base = variant.base

    if base is Variant.EXACT:
        out = bitops.exact_mul_planes(a, b, n)
    elif base is Variant.FLA:
        out = _or_lines_planes(a, b, range(n), n)
    elif base is Variant.HLA:
        even = _or_lines_planes(a, b, range(0, n, 2), n)
        odd = _or_lines_planes(a, b, range(1, n, 2), n)
        if variant.truncated:
            even = bitops.planes_truncate_top(even, n)
            odd = bitops.planes_truncate_top(odd, n)
        out = bitops.planes_add(even, odd, n)
    elif base in (Variant.PC2, Variant.PC3):
        k = 2 if base is Variant.PC2 else 3
        b_msb = jnp.where(msb_always_set, _bit(b, n - 1) | 1, _bit(b, n - 1))
        w = b_msb
        for j in range(1, k):
            w = 2 * w + _bit(b, n - 1 - j)
        head = bitops.planes_from_scaled(a * w, n - k, n)
        lo_start = 1 if (integer_drop_lsb and not msb_always_set) else 0
        low = _or_lines_planes(a, b, range(lo_start, n - k), n)
        out = bitops.planes_or(head, low)
    else:  # pragma: no cover
        raise ValueError(variant)

    if variant.truncated:
        out = bitops.planes_truncate_top(out, n)
    return out


# ---------------------------------------------------------------------------
# Paper Eq. (3): shift-normalized small-multiplier fix for PC2/PC3
# ---------------------------------------------------------------------------

def approx_mul_uint_normalized(
    a: jnp.ndarray, b: jnp.ndarray, n: int, variant: Variant
) -> jnp.ndarray:
    """c = (a * (b << s)) >> s with s chosen so b's MSB is set (paper Eq. 3).

    The paper identifies PC2/PC3's large error for small multipliers (the
    sacrificed LSB line + inactive head lines) and *suggests* this shift
    normalization without evaluating it ("this will however not be studied
    here"). Implemented here as a beyond-paper completion: small multipliers
    are pre-shifted into the favorable MSB-active operating region, the
    wired-OR result is shifted back. Costs one leading-zero count + two
    shifts in the address decoder / output mux.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    # leading-zero count of b within n bits (b==0 handled at the end)
    s = jnp.zeros_like(b)
    bb = b
    for step in (8, 4, 2, 1):  # unrolled CLZ within n bits
        if step < 2 * n:
            take = jnp.where((bb << step) < (1 << n), step, 0)
            take = jnp.where(bb == 0, 0, take)
            bb = jnp.where(take > 0, bb << step, bb)
            s = s + take
    out = approx_mul_uint(a, bb, n, variant, msb_always_set=True)
    out = out >> s
    return jnp.where(b == 0, 0, out)


# ---------------------------------------------------------------------------
# Signed wrapper (paper §3.1: sign-magnitude, NOT two's complement)
# ---------------------------------------------------------------------------

def approx_mul_int_signmag(
    a: jnp.ndarray, b: jnp.ndarray, n: int, variant: Variant, **kw
) -> jnp.ndarray:
    """Signed approximate multiply using sign-magnitude operands (n<=15)."""
    sign = jnp.sign(a.astype(jnp.int32)) * jnp.sign(b.astype(jnp.int32))
    mag = approx_mul_uint(jnp.abs(a), jnp.abs(b), n, variant, **kw)
    return sign * mag


# ---------------------------------------------------------------------------
# Error metric (paper Eq 2; see DESIGN.md §7 for the printed-formula caveat)
# ---------------------------------------------------------------------------

def error_distance(exact: jnp.ndarray, approx: jnp.ndarray) -> jnp.ndarray:
    """ED = |r - r'| / max(r, 1)."""
    exact_f = exact.astype(jnp.float32)
    return jnp.abs(exact_f - approx.astype(jnp.float32)) / jnp.maximum(exact_f, 1.0)
