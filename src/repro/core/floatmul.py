"""Approximate floating-point multiply built on the DAISM mantissa multiplier.

Paper §3.4: only the mantissa product is approximated. The implicit leading 1
is made explicit (so the ``A`` line is always active — the favorable PC2/PC3
operating region), exponents are added exactly, signs are XOR'd, and the
result is renormalized by a single top-bit test (the approximate product is
bounded by ``A <= p~ <= a*b`` so its leading bit is at position 2n-1 or 2n-2).

Convention: ``w`` is the multiplicand (kernel element, stored pre-shifted in
SRAM), ``x`` is the multiplier (input, drives wordline activation). FLA is
operand-symmetric; HLA/PC2/PC3 are not, so the convention matters and follows
the paper ("the multiplicand would be a kernel element and the multiplier
would be the input").

Products are returned as float32 for exact downstream accumulation (the DAISM
accumulator is exact, paper §4.1). For bfloat16 inputs the <=16-bit product
mantissa is represented exactly in f32. For float32 inputs with untruncated
variants the 48-bit product is rounded toward zero to 24 bits on conversion
(|err| < 2^-23 relative — orders of magnitude below the OR-approximation
error; the paper's own *baseline* [43] truncates to 24 bits as well).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import bitops
from .config import Variant, mantissa_bits
from .multiplier import approx_mul_uint, approx_mul_uint_planes

_BIAS = 127  # bf16 and f32 share the 8-bit exponent / bias-127 format


def _normalize_single(prod: jnp.ndarray, n: int):
    """(product in [2^(2n-2), 2^2n)) -> (n-bit mantissa, exp bump)."""
    top = (prod >> (2 * n - 1)) & 1
    man = jnp.where(top == 1, prod >> n, prod >> (n - 1))
    return man & ((1 << n) - 1), top


def _normalize_planes(hi: jnp.ndarray, lo: jnp.ndarray, n: int):
    top = (hi >> (n - 1)) & 1
    man_hi = hi  # bits 2n-1..n
    man_lo = ((hi << 1) | (lo >> (n - 1))) & ((1 << n) - 1)  # bits 2n-2..n-1
    man = jnp.where(top == 1, man_hi, man_lo)
    return man & ((1 << n) - 1), top


def approx_mul_to_f32(x: jnp.ndarray, w: jnp.ndarray, variant: Variant) -> jnp.ndarray:
    """Elementwise approximate product of broadcastable x (input/multiplier)
    and w (weight/multiplicand), returned as float32."""
    variant = Variant(variant)
    if variant is Variant.EXACT:
        return x.astype(jnp.float32) * w.astype(jnp.float32)
    if x.dtype != w.dtype:
        raise ValueError(f"operand dtypes must match, got {x.dtype} vs {w.dtype}")
    n = mantissa_bits(x.dtype)

    sx, ex, mx = bitops.decompose(x)
    sw, ew, mw = bitops.decompose(w)
    sx, ex, mx, sw, ew, mw = jnp.broadcast_arrays(sx, ex, mx, sw, ew, mw)

    if n <= 15:
        prod = approx_mul_uint(mw, mx, n, variant, msb_always_set=True)
        man, bump = _normalize_single(prod, n)
    else:
        hi, lo = approx_mul_uint_planes(mw, mx, n, variant, msb_always_set=True)
        man, bump = _normalize_planes(hi, lo, n)

    sign = sx ^ sw
    exp = ex + ew - _BIAS + bump
    # Map the n-bit mantissa (incl. leading 1) into an f32 mantissa.
    man_f32 = man << (24 - n)
    zero = (mx == 0) | (mw == 0)
    exp = jnp.where(zero, 0, exp)
    man_f32 = jnp.where(zero, 0, man_f32)
    return bitops.compose_f32(sign, exp, man_f32)


def approx_mul(x: jnp.ndarray, w: jnp.ndarray, variant: Variant) -> jnp.ndarray:
    """Elementwise approximate product, returned in the input dtype."""
    out = approx_mul_to_f32(x, w, Variant(variant))
    return out.astype(x.dtype)
