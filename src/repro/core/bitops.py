"""Bit-level primitives for the DAISM multiplier family.

TPU adaptation note (DESIGN.md §2): the paper's partial-product space for a
float32 mantissa multiply is 48 bits wide. TPUs have no 64-bit integer lanes,
so we represent 2n-bit words (n = mantissa width) as a **dual plane**
``(hi, lo)`` of int32 values, each holding ``n`` bits
(``value = hi * 2**n + lo``). Because the wired-OR reduction is carry-free,
OR-accumulation never crosses the plane boundary — the dual-plane form is a
*lossless* reformulation, and the few exact adds the variants need (HLA's
second read, the pre-computed head lines) carry at most one bit across, which
we propagate explicitly.

All functions are pure jnp and shape-polymorphic (operate elementwise on
broadcastable int32 arrays).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from jax import lax

Planes = Tuple[jnp.ndarray, jnp.ndarray]  # (hi, lo), each int32 holding n bits


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``x`` (shared padding helper)."""
    return (x + m - 1) // m * m


def _mask(n: int) -> int:
    return (1 << n) - 1


# ---------------------------------------------------------------------------
# Dual-plane algebra (value = hi * 2**n + lo, 0 <= hi, lo < 2**n, n <= 24)
# ---------------------------------------------------------------------------

def planes_from_shift(a: jnp.ndarray, i: int, n: int) -> Planes:
    """Return ``a << i`` as dual planes, for 0 <= a < 2**n, 0 <= i < n.

    Never overflows int32: the low plane keeps only the bits of ``a`` that
    stay below the boundary, the high plane gets the spill.
    """
    if i == 0:
        return jnp.zeros_like(a), a
    lo = (a & _mask(n - i)) << i
    hi = a >> (n - i)
    return hi, lo


def planes_or(x: Planes, y: Planes) -> Planes:
    return x[0] | y[0], x[1] | y[1]


def planes_select(pred: jnp.ndarray, x: Planes, zero_like: jnp.ndarray) -> Planes:
    z = jnp.zeros_like(zero_like)
    return jnp.where(pred, x[0], z), jnp.where(pred, x[1], z)


def planes_add(x: Planes, y: Planes, n: int) -> Planes:
    """Exact add of two dual-plane values (carry propagates across planes)."""
    lo = x[1] + y[1]
    carry = lo >> n
    return x[0] + y[0] + carry, lo & _mask(n)


def planes_from_scaled(a_times_w: jnp.ndarray, shift: int, n: int) -> Planes:
    """Planes of ``a_times_w << shift`` where ``a_times_w`` fits int32.

    Used for the pre-computed head lines: ``(A+B) = 3a << (n-2)`` etc.
    ``a_times_w`` may be up to 7 * (2**n - 1) (< 2**27 for n=24): safe.
    """
    lo = (a_times_w & _mask(max(n - shift, 0))) << shift if shift < n else jnp.zeros_like(a_times_w)
    hi = a_times_w >> (n - shift) if shift < n else (a_times_w << (shift - n))
    return hi & 0x7FFFFFFF, lo


def planes_truncate_top(x: Planes, n: int) -> Planes:
    """Keep only the top-n columns (bits 2n-1 .. n) => zero the low plane."""
    return x[0], jnp.zeros_like(x[1])


def planes_to_float(x: Planes, n: int) -> jnp.ndarray:
    """Exact float64-free conversion to f32 (value < 2**48 loses low bits in
    f32; used for error analysis at n<=12 and for diagnostics only)."""
    return x[0].astype(jnp.float32) * float(1 << n) + x[1].astype(jnp.float32)


def exact_mul_planes(a: jnp.ndarray, b: jnp.ndarray, n: int) -> Planes:
    """Exact 2n-bit product of n-bit unsigned a, b as dual planes (int32-only).

    Splits each operand into 12-bit halves so every partial product fits in
    int32 (max 2**24 * 7). Valid for n <= 24.
    """
    if n > 24:
        raise ValueError("dual-plane exact multiply supports n <= 24")
    h = 12
    al, ah = a & _mask(h), a >> h
    bl, bh = b & _mask(h), b >> h
    low = al * bl                    # < 2**24
    mid = ah * bl + al * bh          # < 2**25
    high = ah * bh                   # < 2**24
    # value = high*2**24 + mid*2**12 + low ; re-bucket into n-bit planes.
    lo_acc = low + ((mid & _mask(h)) << h)           # < 2**25
    hi_acc = high + (mid >> h) + (lo_acc >> 24)      # carries from bit 24
    lo24 = lo_acc & _mask(24)
    # Now value = hi_acc * 2**24 + lo24. Re-split to n-bit planes.
    if n == 24:
        return hi_acc, lo24
    # n < 24: value < 2**(2n) <= 2**46 ; hi plane = value >> n.
    hi = (hi_acc << (24 - n)) | (lo24 >> n)
    lo = lo24 & _mask(n)
    return hi, lo


# ---------------------------------------------------------------------------
# Float (de)composition. uint arithmetic is done in int32 after widening.
# ---------------------------------------------------------------------------

def decompose_bf16(x: jnp.ndarray):
    """bf16 -> (sign, biased_exp, mantissa_with_implicit_1) int32 each.

    Subnormals are flushed (treated as zero): exp==0 => mantissa 0.
    """
    bits = lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.int32)
    sign = bits >> 15
    exp = (bits >> 7) & 0xFF
    frac = bits & 0x7F
    man = jnp.where(exp > 0, frac | 0x80, 0)
    return sign, exp, man


def compose_bf16(sign: jnp.ndarray, exp: jnp.ndarray, man: jnp.ndarray) -> jnp.ndarray:
    """(sign, biased_exp, 8-bit mantissa incl. leading 1) -> bf16.

    exp <= 0 flushes to zero; exp >= 255 saturates to inf. man==0 => zero.
    """
    zero = (man == 0) | (exp <= 0)
    inf = exp >= 255
    exp_c = jnp.clip(exp, 0, 254)
    bits = (sign << 15) | (exp_c << 7) | (man & 0x7F)
    bits = jnp.where(zero, sign << 15, bits)
    bits = jnp.where(inf & ~zero, (sign << 15) | (0xFF << 7), bits)
    return lax.bitcast_convert_type(bits.astype(jnp.uint16), jnp.bfloat16)


def decompose_f32(x: jnp.ndarray):
    """f32 -> (sign, biased_exp, 24-bit mantissa incl. leading 1) int32."""
    bits = lax.bitcast_convert_type(x, jnp.uint32)
    sign = (bits >> 31).astype(jnp.int32)
    exp = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    frac = (bits & jnp.uint32(0x7FFFFF)).astype(jnp.int32)
    man = jnp.where(exp > 0, frac | (1 << 23), 0)
    return sign, exp, man


def compose_f32(sign: jnp.ndarray, exp: jnp.ndarray, man: jnp.ndarray) -> jnp.ndarray:
    zero = (man == 0) | (exp <= 0)
    inf = exp >= 255
    exp_c = jnp.clip(exp, 0, 254)
    bits = (
        (sign.astype(jnp.uint32) << 31)
        | (exp_c.astype(jnp.uint32) << 23)
        | (man & 0x7FFFFF).astype(jnp.uint32)
    )
    bits = jnp.where(zero, sign.astype(jnp.uint32) << 31, bits)
    bits = jnp.where(inf & ~zero, (sign.astype(jnp.uint32) << 31) | jnp.uint32(0x7F800000), bits)
    return lax.bitcast_convert_type(bits, jnp.float32)


def decompose(x: jnp.ndarray):
    if x.dtype == jnp.bfloat16:
        return decompose_bf16(x)
    if x.dtype == jnp.float32:
        return decompose_f32(x)
    raise ValueError(f"unsupported dtype {x.dtype}")


def compose(sign, exp, man, dtype):
    if jnp.dtype(dtype) == jnp.bfloat16:
        return compose_bf16(sign, exp, man)
    if jnp.dtype(dtype) == jnp.float32:
        return compose_f32(sign, exp, man)
    raise ValueError(f"unsupported dtype {dtype}")
