"""bf16 mantissa-product lookup table — a TPU-native fast path (beyond-paper).

For bfloat16, the effective mantissa is 8 bits with the MSB always set, so
there are only 128 x 128 = 16,384 distinct mantissa pairs. The full 16-bit
approximate product for every pair is precomputed once per variant into a
32 KiB int32 table — small enough to live in VMEM — turning the 8-step
shift/OR chain into a single gather. This is the SRAM "pre-computed line"
idea (paper §3.3) taken to its logical limit on TPU: the entire approximate
multiplication becomes one table read, mirroring DAISM's one-SRAM-read
property. Numerics are bit-identical to the jnp path (asserted in tests).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from . import bitops
from .config import Variant
from .multiplier import approx_mul_uint

_BIAS = 127


@functools.lru_cache(maxsize=None)
def mantissa_product_table(variant: Variant) -> np.ndarray:
    """(128, 128) int32 table: T[mw-128, mx-128] = approx product (16-bit)."""
    import jax

    variant = Variant(variant)
    # force eager evaluation even if first requested inside a jit trace
    with jax.ensure_compile_time_eval():
        mw = jnp.arange(128, 256, dtype=jnp.int32)[:, None]
        mx = jnp.arange(128, 256, dtype=jnp.int32)[None, :]
        t = approx_mul_uint(mw, mx, 8, variant, msb_always_set=True)
        return np.asarray(t, dtype=np.int32)


@functools.lru_cache(maxsize=None)
def shrinkage_factor(variant: Variant) -> float:
    """E[approx/exact] over uniform mantissa pairs (beyond-paper calibration).

    DAISM products are one-sided (approx <= exact): a GEMM output is
    systematically shrunk by ~E[ratio]. Dividing outputs by this constant
    (folded into any output scale for free) removes the bias; tests show it
    cuts mean GEMM error ~2x for FLA and improves end-to-end logit fidelity
    (tests/test_gemm.py::test_calibration_reduces_bias).
    """
    import jax

    t = mantissa_product_table(Variant(variant)).astype(np.float64)
    mw = np.arange(128, 256, dtype=np.float64)[:, None]
    mx = np.arange(128, 256, dtype=np.float64)[None, :]
    return float((t / (mw * mx)).mean())


def approx_mul_to_f32_lut(x: jnp.ndarray, w: jnp.ndarray, variant: Variant) -> jnp.ndarray:
    """bf16-only elementwise approximate product via table gather -> f32.

    Bit-identical to ``floatmul.approx_mul_to_f32`` for bfloat16 operands.
    """
    if x.dtype != jnp.bfloat16 or w.dtype != jnp.bfloat16:
        raise ValueError("LUT path is bfloat16-only")
    table = jnp.asarray(mantissa_product_table(Variant(variant)))
    sx, ex, mx = bitops.decompose(x)
    sw, ew, mw = bitops.decompose(w)
    sx, ex, mx, sw, ew, mw = jnp.broadcast_arrays(sx, ex, mx, sw, ew, mw)

    idx = (jnp.maximum(mw - 128, 0) << 7) | jnp.maximum(mx - 128, 0)
    prod = jnp.take(table.reshape(-1), idx)
    top = (prod >> 15) & 1
    man = jnp.where(top == 1, prod >> 8, prod >> 7) & 0xFF

    sign = sx ^ sw
    exp = ex + ew - _BIAS + top
    man_f32 = man << 16
    zero = (mx == 0) | (mw == 0)
    exp = jnp.where(zero, 0, exp)
    man_f32 = jnp.where(zero, 0, man_f32)
    return bitops.compose_f32(sign, exp, man_f32)
