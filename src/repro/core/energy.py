"""Analytical energy model for the DAISM multiplier family (paper §5.2).

Reproduces Eq (4)–(6) and the Fig 7/8 studies. The paper uses CACTI +
Synopsys DC at NANGATE 45nm; neither tool is available offline, so the
constants below are drawn from published 45nm numbers (Horowitz, "Computing's
energy problem", ISSCC 2014; CACTI 7 scaling trends; Eyeriss JSSC'17 relative
access costs) and are recorded as an explicit :class:`TechnologyModel` so the
*structure* of the model is the paper's and the constants are swappable. We
therefore validate the paper's *relative* claims (ordering, ±10 % of the
headline −25 % energy), not absolute pJ — stated in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .config import Variant

# ---------------------------------------------------------------------------
# Technology constants (45nm, ~0.9 V)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TechnologyModel:
    """45nm energy constants.

    Sources:
      * fp32 multiply 3.7 pJ / fp16 multiply 1.1 pJ — Horowitz ISSCC'14.
        bfloat16 ~ fp16 multiplier energy (same 8-bit-ish mantissa datapath;
        Eq (6) scaling).
      * SRAM read energy grows ~sqrt(capacity) for square arrays (CACTI 7
        trend); anchored at 10 pJ per 64-bit access of an 8 KB array
        (Horowitz) => ~2.5 pJ/word amortized to our wide-row reads.
      * register-file read ~0.5 pJ/16-bit operand (Eyeriss JSSC'17 reports
        RF access ~ 1 MAC energy).
      * SRAM energy breakdown across decoder/wordline/bitline/sense/IO —
        CACTI 7 component reports (bitline+sense dominate).
    """

    e_mul_f32: float = 3.7          # pJ, exact fp32 multiplier
    e_mul_bf16: float = 1.1         # pJ, exact bf16 multiplier (E_sim16/E_sim32 scale)
    trunc_factor_f32: float = 0.62  # T in Eq (6): 48->24-bit output, linear in
    trunc_factor_bf16: float = 0.80 # truncated mantissa-array width (Yin'16 data)
    e_reg_16b: float = 0.5          # pJ, register-file read per 16-bit operand
    e_add_16b: float = 0.05         # pJ, 16-bit adder (HLA merge)
    e_add_8b: float = 0.03          # pJ, exponent adder
    e_sram_8kb_read: float = 12.0   # pJ, full 256-bit row read of an 8 KB bank
    sram_sqrt_scale: bool = True    # E(read) ~ sqrt(capacity) for square banks
    # component fractions of an SRAM read (CACTI-style): decoder, wordline,
    # bitline, sense-amp, io
    frac_dec: float = 0.06
    frac_wl: float = 0.04
    frac_bl: float = 0.52
    frac_sense: float = 0.26
    frac_io: float = 0.12

    def sram_read_energy(self, kbytes: float) -> float:
        """Energy of one full-row read of a square ``kbytes`` bank (pJ)."""
        if self.sram_sqrt_scale:
            return self.e_sram_8kb_read * math.sqrt(kbytes / 8.0)
        return self.e_sram_8kb_read * (kbytes / 8.0)


TECH_45NM = TechnologyModel()


# ---------------------------------------------------------------------------
# Multiplier geometry
# ---------------------------------------------------------------------------

def mantissa_width(dtype: str) -> int:
    return {"bfloat16": 8, "float32": 24}[dtype]


def product_bits(dtype: str, truncated: bool) -> int:
    n = mantissa_width(dtype)
    return n if truncated else 2 * n


def concurrent_mults(dtype: str, truncated: bool, bus_bits: int) -> int:
    """N in Eq (5): multiplications per wide-row read.

    Each kernel element occupies a column field of 2x the product width
    (pre-shifted partial-product storage), reproducing the paper's stated
    32 KB/512-bit numbers: bf16 truncated -> 32, untruncated -> 16.
    """
    field = 2 * product_bits(dtype, truncated)
    return max(1, bus_bits // field)


def active_wordlines(variant: Variant, dtype: str) -> int:
    """Worst-case simultaneously-active wordlines per read (paper: 7 for
    PC2_tr bf16 — head line + 6 low lines)."""
    n = mantissa_width(dtype)
    base = Variant(variant).base
    if base is Variant.FLA:
        return n
    if base is Variant.HLA:
        return (n + 1) // 2  # per read; two reads happen
    if base is Variant.PC2:
        return 1 + (n - 2)
    if base is Variant.PC3:
        return 1 + (n - 3)
    raise ValueError(variant)


# ---------------------------------------------------------------------------
# Eq (4): Eyeriss-style baseline — RF read + PE-local SRAM read + multiplier
# ---------------------------------------------------------------------------

def eyeriss_energy_per_mult(
    dtype: str = "bfloat16",
    *,
    truncated: bool = True,
    pe_spad_kb: float = 0.5,
    tech: TechnologyModel = TECH_45NM,
) -> Dict[str, float]:
    s = tech.sram_read_energy(pe_spad_kb)
    # narrow PE-spad read: one operand word, not a wide row
    word_fraction = product_bits(dtype, False) / 256.0  # vs the 256-bit ref row
    s_word = s * max(word_fraction, 0.10)
    if dtype == "bfloat16":
        e_mul = tech.e_mul_bf16 * (tech.trunc_factor_bf16 if truncated else 1.0)
        e_reg = tech.e_reg_16b
    else:
        e_mul = tech.e_mul_f32 * (tech.trunc_factor_f32 if truncated else 1.0)
        e_reg = tech.e_reg_16b * 2
    return {
        "register_file": e_reg,
        "sram_decoder": s_word * tech.frac_dec,
        "sram_bitline": s_word * tech.frac_bl,
        "sram_sense": s_word * tech.frac_sense,
        "sram_wordline": s_word * tech.frac_wl,
        "sram_io": s_word * tech.frac_io,
        "multiplier": e_mul,
    }


# ---------------------------------------------------------------------------
# Eq (5): DAISM — amortized RF read + one (or two) wide multi-wordline reads
# ---------------------------------------------------------------------------

def daism_energy_per_mult(
    variant: Variant,
    dtype: str = "bfloat16",
    *,
    bank_kb: float = 32.0,
    bus_bits: int = 512,
    tech: TechnologyModel = TECH_45NM,
) -> Dict[str, float]:
    variant = Variant(variant)
    if variant is Variant.EXACT:
        raise ValueError("use eyeriss_energy_per_mult for the exact baseline")
    truncated = variant.truncated
    n_par = concurrent_mults(dtype, truncated, bus_bits)
    reads = variant.memory_reads
    n_wl = active_wordlines(variant, dtype)

    s = tech.sram_read_energy(bank_kb)
    e_read = (
        s * tech.frac_dec
        + s * tech.frac_bl
        + s * tech.frac_sense
        + s * tech.frac_io
        + n_wl * (s * tech.frac_wl)
    )
    e_reg = tech.e_reg_16b if dtype == "bfloat16" else tech.e_reg_16b * 2
    out = {
        "register_file": e_reg / n_par,
        "sram_decoder": reads * s * tech.frac_dec / n_par,
        "sram_bitline": reads * s * tech.frac_bl / n_par,
        "sram_sense": reads * s * tech.frac_sense / n_par,
        "sram_io": reads * s * tech.frac_io / n_par,
        "sram_wordline": reads * n_wl * s * tech.frac_wl / n_par,
        "multiplier": 0.0,  # the multiplication happens in the read itself
    }
    if variant.base is Variant.HLA:  # merge adder for the two reads
        width = product_bits(dtype, truncated)
        out["adder"] = tech.e_add_16b * width / 16.0
    return out


def exponent_handling_energy(dtype: str, tech: TechnologyModel = TECH_45NM) -> float:
    """Common exponent-add + normalization cost (Fig 8), per multiplication."""
    return tech.e_add_8b * 2  # exponent add + realign increment


def total(breakdown: Dict[str, float]) -> float:
    return sum(breakdown.values())


def relative_improvement(
    variant: Variant = Variant.PC3_TR,
    dtype: str = "bfloat16",
    *,
    bank_kb: float = 32.0,
    bus_bits: int = 512,
    with_exponent: bool = True,
    tech: TechnologyModel = TECH_45NM,
) -> float:
    """(E_baseline - E_daism) / E_baseline, optionally incl. exponent cost."""
    e_base = total(eyeriss_energy_per_mult(dtype, truncated=True, tech=tech))
    e_ours = total(daism_energy_per_mult(
        variant, dtype, bank_kb=bank_kb, bus_bits=bus_bits, tech=tech))
    if with_exponent:
        e_exp = exponent_handling_energy(dtype, tech)
        e_base += e_exp
        e_ours += e_exp
    return (e_base - e_ours) / e_base
